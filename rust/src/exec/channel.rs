//! Allocation-free blocking channels for the central inference path.
//!
//! `std::sync::mpsc` heap-allocates a queue node on every `send`, which
//! makes it impossible for a round-trip built on it to pass the
//! counting-allocator gate (`micro_batcher --quick`). This channel is
//! the boring alternative: a `Mutex<VecDeque>` plus one `Condvar`. Sends
//! push into a deque whose capacity settles at the steady-state
//! in-flight population, so a warmed-up path never enters the allocator;
//! receivers block on the condvar (timeout-aware, for the batcher's
//! flush window). Sends ring the condvar doorbell only when a receiver
//! is actually parked — a burst of submissions against a busy consumer
//! pays zero notify syscalls (see [`Sender::send`]).
//!
//! Two construction patterns:
//!
//! * [`channel`] — classic mpsc: the returned [`Sender`] (and its
//!   clones) keep the channel open; `recv` reports disconnect once every
//!   sender is gone. The batcher's input queue uses this, mirroring the
//!   seed's "batcher exits when all handles drop" semantics.
//! * [`mailbox`] — a receiver with **zero** initial senders; producers
//!   are minted per message with [`Receiver::sender`]. Disconnect means
//!   "nothing currently holds a route to this mailbox", which is exactly
//!   the liveness question a policy client's `wait` needs to ask: every
//!   in-flight submission holds a minted sender (inside the queued
//!   `InferItem`, then inside the batcher's routing table), so the
//!   count only reaches zero when every outstanding submission has been
//!   answered or dropped — e.g. when the batcher died and drained.
//!
//! Dropping the [`Receiver`] closes the channel *and drops everything
//! still queued*, so values holding resources (minted senders, pooled
//! slabs) are released promptly instead of idling until the last sender
//! goes away.

use crate::metrics::Counter;
use std::collections::VecDeque;
use std::sync::{Arc, Condvar, Mutex};
use std::time::{Duration, Instant};

/// Why a timed receive returned without a value.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum RecvTimeoutError {
    /// No message arrived inside the window (senders may still exist).
    Timeout,
    /// Queue empty and no sender is alive.
    Disconnected,
}

struct State<T> {
    q: VecDeque<T>,
    senders: usize,
    rx_alive: bool,
    /// Receivers currently parked on the condvar. `send` only rings the
    /// doorbell (notify + syscall) when this is non-zero: a receiver
    /// that is busy draining the queue costs senders nothing. No wakeup
    /// is lost because the receiver increments this under the same lock
    /// *before* `Condvar::wait` atomically releases it — any send that
    /// observes `waiters == 0` happened strictly before the park, and
    /// its value is already in `q` when the receiver re-checks.
    waiters: usize,
}

struct Shared<T> {
    state: Mutex<State<T>>,
    cv: Condvar,
    /// Optional doorbell counter: one `inc` per `send`-side
    /// `notify_one` actually issued. The batcher's input queue attaches
    /// `batcher.queue_wakeups` here; with doorbell batching a burst of
    /// submissions against a busy batcher counts a single wakeup (or
    /// none), not one per item.
    wakeups: Option<Counter>,
}

/// Producer handle. Cloning registers another sender; dropping the last
/// one disconnects the receiver.
pub struct Sender<T> {
    shared: Arc<Shared<T>>,
}

/// Consumer handle (single receiver; not cloneable).
pub struct Receiver<T> {
    shared: Arc<Shared<T>>,
}

/// Classic mpsc pair. `capacity` presizes the deque (a hint: the queue
/// still grows if the in-flight population exceeds it — growth is the
/// warmup the zero-allocation gate excludes).
pub fn channel<T>(capacity: usize) -> (Sender<T>, Receiver<T>) {
    channel_inner(capacity, None)
}

/// [`channel`] with a wakeup counter attached: every `send`-side
/// condvar notify bumps it. Used for the batcher input queue
/// (`batcher.queue_wakeups`).
pub fn channel_counted<T>(
    capacity: usize,
    wakeups: Counter,
) -> (Sender<T>, Receiver<T>) {
    channel_inner(capacity, Some(wakeups))
}

fn channel_inner<T>(
    capacity: usize,
    wakeups: Option<Counter>,
) -> (Sender<T>, Receiver<T>) {
    let shared = Arc::new(Shared {
        state: Mutex::new(State {
            q: VecDeque::with_capacity(capacity),
            senders: 1,
            rx_alive: true,
            waiters: 0,
        }),
        cv: Condvar::new(),
        wakeups,
    });
    (
        Sender {
            shared: shared.clone(),
        },
        Receiver { shared },
    )
}

/// A receiver with no initial senders (see the module docs): mint one
/// per producer with [`Receiver::sender`].
pub fn mailbox<T>(capacity: usize) -> Receiver<T> {
    let shared = Arc::new(Shared {
        state: Mutex::new(State {
            q: VecDeque::with_capacity(capacity),
            senders: 0,
            rx_alive: true,
            waiters: 0,
        }),
        cv: Condvar::new(),
        wakeups: None,
    });
    Receiver { shared }
}

impl<T> Sender<T> {
    /// Queue a value. Returns it back if the receiver is gone.
    ///
    /// Doorbell batching: the condvar is only notified when a receiver
    /// is parked in `recv`/`recv_timeout`. A receiver busy draining a
    /// burst re-checks the queue under the lock before it ever parks,
    /// so skipping the notify for it is safe — and saves the futex
    /// syscall that made per-submission wakeups the dominant cost of
    /// the old protocol (`batcher.queue_wakeups` measured it at one
    /// per send).
    pub fn send(&self, v: T) -> Result<(), T> {
        let mut st = self.shared.state.lock().unwrap();
        if !st.rx_alive {
            return Err(v);
        }
        st.q.push_back(v);
        let ring = st.waiters > 0;
        drop(st);
        if ring {
            self.shared.cv.notify_one();
            if let Some(c) = &self.shared.wakeups {
                c.inc();
            }
        }
        Ok(())
    }
}

impl<T> Clone for Sender<T> {
    fn clone(&self) -> Self {
        self.shared.state.lock().unwrap().senders += 1;
        Sender {
            shared: self.shared.clone(),
        }
    }
}

impl<T> Drop for Sender<T> {
    fn drop(&mut self) {
        let mut st = self.shared.state.lock().unwrap();
        st.senders -= 1;
        let gone = st.senders == 0;
        drop(st);
        if gone {
            // Wake a receiver blocked in recv so it can see disconnect.
            self.shared.cv.notify_all();
        }
    }
}

impl<T> Receiver<T> {
    /// Mint a counted producer for this receiver (the mailbox pattern).
    pub fn sender(&self) -> Sender<T> {
        self.shared.state.lock().unwrap().senders += 1;
        Sender {
            shared: self.shared.clone(),
        }
    }

    /// Blocking receive; `None` once the queue is empty and no sender
    /// is alive.
    pub fn recv(&self) -> Option<T> {
        let mut st = self.shared.state.lock().unwrap();
        loop {
            if let Some(v) = st.q.pop_front() {
                return Some(v);
            }
            if st.senders == 0 {
                return None;
            }
            st.waiters += 1;
            st = self.shared.cv.wait(st).unwrap();
            st.waiters -= 1;
        }
    }

    /// Blocking receive with a deadline window.
    pub fn recv_timeout(&self, timeout: Duration) -> Result<T, RecvTimeoutError> {
        let deadline = Instant::now() + timeout;
        let mut st = self.shared.state.lock().unwrap();
        loop {
            if let Some(v) = st.q.pop_front() {
                return Ok(v);
            }
            if st.senders == 0 {
                return Err(RecvTimeoutError::Disconnected);
            }
            let now = Instant::now();
            if now >= deadline {
                return Err(RecvTimeoutError::Timeout);
            }
            st.waiters += 1;
            let (guard, _) = self
                .shared
                .cv
                .wait_timeout(st, deadline - now)
                .unwrap();
            st = guard;
            st.waiters -= 1;
        }
    }

    /// Non-blocking receive (tests / drain loops).
    pub fn try_recv(&self) -> Option<T> {
        self.shared.state.lock().unwrap().q.pop_front()
    }
}

impl<T> Drop for Receiver<T> {
    fn drop(&mut self) {
        let mut st = self.shared.state.lock().unwrap();
        st.rx_alive = false;
        // Take the queued values out, then drop them AFTER releasing
        // the lock: anything they hold (minted mailbox senders, pooled
        // slabs) must release immediately — and a queued value owning a
        // Sender back to *this* channel would self-deadlock if its Drop
        // re-locked the state mutex we are holding.
        let drained = std::mem::take(&mut st.q);
        drop(st);
        drop(drained);
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use std::time::Duration;

    #[test]
    fn fifo_order_across_threads() {
        let (tx, rx) = channel::<u32>(8);
        std::thread::scope(|s| {
            s.spawn(move || {
                for i in 0..100 {
                    tx.send(i).unwrap();
                }
            });
            for i in 0..100 {
                assert_eq!(rx.recv(), Some(i));
            }
        });
        // The spawned sender dropped: disconnect surfaces.
        assert_eq!(rx.recv(), None);
    }

    #[test]
    fn recv_timeout_times_out_then_delivers() {
        let (tx, rx) = channel::<u8>(4);
        assert_eq!(
            rx.recv_timeout(Duration::from_micros(200)),
            Err(RecvTimeoutError::Timeout)
        );
        tx.send(7).unwrap();
        assert_eq!(rx.recv_timeout(Duration::from_millis(50)), Ok(7));
        drop(tx);
        assert_eq!(
            rx.recv_timeout(Duration::from_millis(50)),
            Err(RecvTimeoutError::Disconnected)
        );
    }

    #[test]
    fn clone_keeps_channel_open_until_last_sender_drops() {
        let (tx, rx) = channel::<u8>(4);
        let tx2 = tx.clone();
        drop(tx);
        tx2.send(1).unwrap();
        assert_eq!(rx.recv(), Some(1));
        drop(tx2);
        assert_eq!(rx.recv(), None);
    }

    #[test]
    fn send_after_receiver_drop_returns_the_value() {
        let (tx, rx) = channel::<String>(4);
        drop(rx);
        let back = tx.send("lost".into()).unwrap_err();
        assert_eq!(back, "lost");
    }

    #[test]
    fn mailbox_disconnects_only_while_no_minted_sender_lives() {
        let mb = mailbox::<u8>(4);
        // No producers yet: an empty mailbox reads as disconnected.
        assert_eq!(
            mb.recv_timeout(Duration::from_millis(1)),
            Err(RecvTimeoutError::Disconnected)
        );
        let tx = mb.sender();
        tx.send(3).unwrap();
        drop(tx);
        // Queued value survives the producer.
        assert_eq!(mb.recv(), Some(3));
        assert_eq!(mb.recv(), None);
        // Minting a new producer revives the channel.
        let tx = mb.sender();
        tx.send(4).unwrap();
        assert_eq!(mb.recv(), Some(4));
    }

    #[test]
    fn doorbell_skips_notify_while_no_receiver_is_parked() {
        let c = Counter::default();
        let (tx, rx) = channel_counted::<u8>(4, c.clone());
        // Nobody is parked on the condvar: a burst enqueues silently.
        for i in 0..5 {
            tx.send(i).unwrap();
        }
        assert_eq!(c.get(), 0, "busy-consumer sends must not ring the doorbell");
        // Draining a non-empty queue never parks either.
        for i in 0..5 {
            assert_eq!(rx.recv(), Some(i));
        }
        assert_eq!(c.get(), 0);
        drop(rx);
        // A rejected send (receiver gone) never notifies.
        assert!(tx.send(9).is_err());
        assert_eq!(c.get(), 0);
    }

    #[test]
    fn doorbell_rings_once_for_a_parked_receiver() {
        let c = Counter::default();
        let (tx, rx) = channel_counted::<u8>(4, c.clone());
        std::thread::scope(|s| {
            let rx = &rx;
            let h = s.spawn(move || rx.recv_timeout(Duration::from_secs(10)));
            // Give the receiver time to park; if it has not parked yet
            // the send still lands in the queue (no lost value), but the
            // wakeup assertion below is what this test pins.
            std::thread::sleep(Duration::from_millis(50));
            tx.send(9).unwrap();
            assert_eq!(h.join().unwrap(), Ok(9));
        });
        assert_eq!(c.get(), 1, "exactly one notify to wake the parked receiver");
        // The plain constructor stays uncounted.
        let (tx2, rx2) = channel::<u8>(4);
        tx2.send(1).unwrap();
        assert_eq!(rx2.recv(), Some(1));
        assert_eq!(c.get(), 1);
    }

    #[test]
    fn receiver_drop_drops_queued_values() {
        // A queued value holding a minted sender to another mailbox must
        // be released when the receiver dies — the waiter on that other
        // mailbox sees disconnect instead of hanging (the batcher-death
        // drain path relies on this).
        let inner = mailbox::<u8>(2);
        let (tx, rx) = channel::<Sender<u8>>(2);
        assert!(tx.send(inner.sender()).is_ok());
        drop(rx); // drains the queue, dropping the minted sender
        assert_eq!(
            inner.recv_timeout(Duration::from_millis(1)),
            Err(RecvTimeoutError::Disconnected)
        );
        drop(tx);
    }
}
