//! Deterministic PRNGs for simulation and testing.
//!
//! The simulator and property tests need fast, seedable, reproducible
//! randomness with independent streams per component (actor, env, sweep
//! point). `rand` is not in the offline crate set, so we implement
//! SplitMix64 (seed expansion / stream splitting) and PCG32 (the workhorse
//! generator) — both are tiny, well-studied algorithms with published
//! reference outputs that the unit tests pin.

/// SplitMix64: used to expand seeds and derive independent streams.
#[derive(Clone, Debug)]
pub struct SplitMix64 {
    state: u64,
}

impl SplitMix64 {
    pub fn new(seed: u64) -> Self {
        Self { state: seed }
    }

    #[inline]
    pub fn next_u64(&mut self) -> u64 {
        self.state = self.state.wrapping_add(0x9E37_79B9_7F4A_7C15);
        let mut z = self.state;
        z = (z ^ (z >> 30)).wrapping_mul(0xBF58_476D_1CE4_E5B9);
        z = (z ^ (z >> 27)).wrapping_mul(0x94D0_49BB_1331_11EB);
        z ^ (z >> 31)
    }
}

/// PCG32 (XSH-RR 64/32): the main generator.
#[derive(Clone, Debug)]
pub struct Pcg32 {
    state: u64,
    inc: u64,
}

const PCG_MULT: u64 = 6_364_136_223_846_793_005;

impl Pcg32 {
    /// Standard PCG32 seeding procedure.
    pub fn new(seed: u64, stream: u64) -> Self {
        let mut rng = Self {
            state: 0,
            inc: (stream << 1) | 1,
        };
        rng.next_u32();
        rng.state = rng.state.wrapping_add(seed);
        rng.next_u32();
        rng
    }

    /// Derive a generator from a single seed (stream from SplitMix64).
    pub fn seeded(seed: u64) -> Self {
        let mut sm = SplitMix64::new(seed);
        let s = sm.next_u64();
        let inc = sm.next_u64();
        Self::new(s, inc)
    }

    /// Independent child stream `i` (for per-actor / per-env rngs).
    pub fn split(&mut self, i: u64) -> Self {
        let s = self.next_u64() ^ SplitMix64::new(i).next_u64();
        let inc = self.next_u64() ^ i.rotate_left(17);
        Self::new(s, inc)
    }

    #[inline]
    pub fn next_u32(&mut self) -> u32 {
        let old = self.state;
        self.state = old.wrapping_mul(PCG_MULT).wrapping_add(self.inc);
        let xorshifted = (((old >> 18) ^ old) >> 27) as u32;
        let rot = (old >> 59) as u32;
        xorshifted.rotate_right(rot)
    }

    #[inline]
    pub fn next_u64(&mut self) -> u64 {
        ((self.next_u32() as u64) << 32) | self.next_u32() as u64
    }

    /// Uniform in [0, 1).
    #[inline]
    pub fn next_f64(&mut self) -> f64 {
        (self.next_u64() >> 11) as f64 * (1.0 / (1u64 << 53) as f64)
    }

    /// Uniform in [0, 1) single precision.
    #[inline]
    pub fn next_f32(&mut self) -> f32 {
        (self.next_u32() >> 8) as f32 * (1.0 / (1u32 << 24) as f32)
    }

    /// Unbiased uniform integer in [0, bound) (Lemire's method).
    #[inline]
    pub fn next_below(&mut self, bound: u32) -> u32 {
        debug_assert!(bound > 0);
        loop {
            let x = self.next_u32() as u64;
            let m = x * bound as u64;
            let low = m as u32;
            if low >= bound {
                return (m >> 32) as u32;
            }
            // Rejection zone: low < bound; accept unless in biased region.
            let threshold = bound.wrapping_neg() % bound;
            if low >= threshold {
                return (m >> 32) as u32;
            }
        }
    }

    /// Uniform usize index in [0, len).
    #[inline]
    pub fn index(&mut self, len: usize) -> usize {
        debug_assert!(len > 0 && len <= u32::MAX as usize);
        self.next_below(len as u32) as usize
    }

    /// Bernoulli(p).
    #[inline]
    pub fn chance(&mut self, p: f64) -> bool {
        self.next_f64() < p
    }

    /// Standard normal via Box-Muller (one value per call; simple > fast).
    pub fn next_normal(&mut self) -> f64 {
        loop {
            let u = self.next_f64();
            if u > 1e-12 {
                let v = self.next_f64();
                return (-2.0 * u.ln()).sqrt()
                    * (2.0 * std::f64::consts::PI * v).cos();
            }
        }
    }

    /// Exponential with mean `mean` (for event inter-arrival times).
    pub fn next_exp(&mut self, mean: f64) -> f64 {
        loop {
            let u = self.next_f64();
            if u > 1e-12 {
                return -mean * u.ln();
            }
        }
    }

    /// Fisher-Yates shuffle.
    pub fn shuffle<T>(&mut self, xs: &mut [T]) {
        for i in (1..xs.len()).rev() {
            let j = self.index(i + 1);
            xs.swap(i, j);
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn splitmix_reference_values() {
        // Reference outputs for seed 1234567 (from the published algorithm).
        let mut sm = SplitMix64::new(1234567);
        let a = sm.next_u64();
        let b = sm.next_u64();
        assert_ne!(a, b);
        // Deterministic across runs:
        let mut sm2 = SplitMix64::new(1234567);
        assert_eq!(sm2.next_u64(), a);
        assert_eq!(sm2.next_u64(), b);
    }

    #[test]
    fn pcg32_reference_sequence() {
        // PCG reference: seed=42, stream=54 produces this well-known prefix.
        let mut rng = Pcg32::new(42, 54);
        let expected: [u32; 6] = [
            0xa15c_02b7, 0x7b47_f409, 0xba1d_3330, 0x83d2_f293, 0xbfa4_784b,
            0xcbed_606e,
        ];
        for e in expected {
            assert_eq!(rng.next_u32(), e);
        }
    }

    #[test]
    fn f64_in_unit_interval() {
        let mut rng = Pcg32::seeded(7);
        for _ in 0..10_000 {
            let x = rng.next_f64();
            assert!((0.0..1.0).contains(&x));
        }
    }

    #[test]
    fn next_below_unbiased_small_bound() {
        let mut rng = Pcg32::seeded(3);
        let mut counts = [0u32; 5];
        for _ in 0..50_000 {
            counts[rng.next_below(5) as usize] += 1;
        }
        for c in counts {
            assert!((8_000..12_000).contains(&c), "count {c}");
        }
    }

    #[test]
    fn split_streams_differ() {
        let mut root = Pcg32::seeded(99);
        let mut a = root.split(0);
        let mut b = root.split(1);
        let va: Vec<u32> = (0..8).map(|_| a.next_u32()).collect();
        let vb: Vec<u32> = (0..8).map(|_| b.next_u32()).collect();
        assert_ne!(va, vb);
    }

    #[test]
    fn normal_moments() {
        let mut rng = Pcg32::seeded(11);
        let n = 100_000;
        let (mut sum, mut sq) = (0.0, 0.0);
        for _ in 0..n {
            let x = rng.next_normal();
            sum += x;
            sq += x * x;
        }
        let mean = sum / n as f64;
        let var = sq / n as f64 - mean * mean;
        assert!(mean.abs() < 0.02, "mean {mean}");
        assert!((var - 1.0).abs() < 0.05, "var {var}");
    }

    #[test]
    fn exp_mean() {
        let mut rng = Pcg32::seeded(13);
        let n = 100_000;
        let mean = (0..n).map(|_| rng.next_exp(3.0)).sum::<f64>() / n as f64;
        assert!((mean - 3.0).abs() < 0.1, "mean {mean}");
    }

    #[test]
    fn shuffle_is_permutation() {
        let mut rng = Pcg32::seeded(5);
        let mut xs: Vec<u32> = (0..100).collect();
        rng.shuffle(&mut xs);
        let mut sorted = xs.clone();
        sorted.sort_unstable();
        assert_eq!(sorted, (0..100).collect::<Vec<_>>());
        assert_ne!(xs, (0..100).collect::<Vec<_>>());
    }

    #[test]
    fn chance_extremes() {
        let mut rng = Pcg32::seeded(17);
        assert!(!rng.chance(0.0));
        assert!(rng.chance(1.0));
    }
}
