//! Minimal JSON parser/writer (serde is not in the offline crate set).
//!
//! Supports the full JSON grammar we produce and consume: the AOT
//! `manifest.json`, `kernel_trace.json`, the tensor-bundle header, and the
//! CSV/JSON reports the benches emit. Numbers are f64 (adequate: all our
//! integers are < 2^53). Object key order is preserved.

use std::collections::BTreeMap;
use std::fmt::Write as _;

#[derive(Clone, Debug, PartialEq)]
pub enum Value {
    Null,
    Bool(bool),
    Num(f64),
    Str(String),
    Arr(Vec<Value>),
    Obj(Vec<(String, Value)>),
}

#[derive(Debug)]
pub enum JsonError {
    Eof(usize),
    Unexpected(char, usize),
    BadNumber(usize),
    BadEscape(char, usize),
    Trailing(usize),
}

impl std::fmt::Display for JsonError {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        match self {
            JsonError::Eof(at) => write!(f, "unexpected end of input at byte {at}"),
            JsonError::Unexpected(c, at) => {
                write!(f, "unexpected character '{c}' at byte {at}")
            }
            JsonError::BadNumber(at) => write!(f, "invalid number at byte {at}"),
            JsonError::BadEscape(c, at) => write!(f, "invalid escape '\\{c}' at byte {at}"),
            JsonError::Trailing(at) => write!(f, "trailing garbage at byte {at}"),
        }
    }
}

impl std::error::Error for JsonError {}

impl Value {
    pub fn parse(text: &str) -> Result<Value, JsonError> {
        let bytes = text.as_bytes();
        let mut p = Parser { bytes, pos: 0 };
        p.skip_ws();
        let v = p.value()?;
        p.skip_ws();
        if p.pos != bytes.len() {
            return Err(JsonError::Trailing(p.pos));
        }
        Ok(v)
    }

    // -- accessors ---------------------------------------------------------

    pub fn get(&self, key: &str) -> Option<&Value> {
        match self {
            Value::Obj(kvs) => kvs.iter().find(|(k, _)| k == key).map(|(_, v)| v),
            _ => None,
        }
    }

    pub fn idx(&self, i: usize) -> Option<&Value> {
        match self {
            Value::Arr(xs) => xs.get(i),
            _ => None,
        }
    }

    pub fn as_f64(&self) -> Option<f64> {
        match self {
            Value::Num(x) => Some(*x),
            _ => None,
        }
    }

    pub fn as_u64(&self) -> Option<u64> {
        self.as_f64().map(|x| x as u64)
    }

    pub fn as_usize(&self) -> Option<usize> {
        self.as_f64().map(|x| x as usize)
    }

    pub fn as_str(&self) -> Option<&str> {
        match self {
            Value::Str(s) => Some(s),
            _ => None,
        }
    }

    pub fn as_bool(&self) -> Option<bool> {
        match self {
            Value::Bool(b) => Some(*b),
            _ => None,
        }
    }

    pub fn as_arr(&self) -> Option<&[Value]> {
        match self {
            Value::Arr(xs) => Some(xs),
            _ => None,
        }
    }

    pub fn as_obj(&self) -> Option<&[(String, Value)]> {
        match self {
            Value::Obj(kvs) => Some(kvs),
            _ => None,
        }
    }

    /// Dotted-path lookup: `v.path("a.b.c")`.
    pub fn path(&self, dotted: &str) -> Option<&Value> {
        let mut cur = self;
        for part in dotted.split('.') {
            cur = cur.get(part)?;
        }
        Some(cur)
    }

    // -- writer ------------------------------------------------------------

    pub fn to_string(&self) -> String {
        let mut out = String::new();
        self.write(&mut out);
        out
    }

    fn write(&self, out: &mut String) {
        match self {
            Value::Null => out.push_str("null"),
            Value::Bool(b) => out.push_str(if *b { "true" } else { "false" }),
            Value::Num(x) => {
                if x.fract() == 0.0 && x.abs() < 9.0e15 {
                    let _ = write!(out, "{}", *x as i64);
                } else {
                    let _ = write!(out, "{x}");
                }
            }
            Value::Str(s) => write_escaped(s, out),
            Value::Arr(xs) => {
                out.push('[');
                for (i, x) in xs.iter().enumerate() {
                    if i > 0 {
                        out.push(',');
                    }
                    x.write(out);
                }
                out.push(']');
            }
            Value::Obj(kvs) => {
                out.push('{');
                for (i, (k, v)) in kvs.iter().enumerate() {
                    if i > 0 {
                        out.push(',');
                    }
                    write_escaped(k, out);
                    out.push(':');
                    v.write(out);
                }
                out.push('}');
            }
        }
    }
}

fn write_escaped(s: &str, out: &mut String) {
    out.push('"');
    for c in s.chars() {
        match c {
            '"' => out.push_str("\\\""),
            '\\' => out.push_str("\\\\"),
            '\n' => out.push_str("\\n"),
            '\r' => out.push_str("\\r"),
            '\t' => out.push_str("\\t"),
            c if (c as u32) < 0x20 => {
                let _ = write!(out, "\\u{:04x}", c as u32);
            }
            c => out.push(c),
        }
    }
    out.push('"');
}

// Convenience constructors for report code.
impl From<f64> for Value {
    fn from(x: f64) -> Self {
        Value::Num(x)
    }
}
impl From<u64> for Value {
    fn from(x: u64) -> Self {
        Value::Num(x as f64)
    }
}
impl From<usize> for Value {
    fn from(x: usize) -> Self {
        Value::Num(x as f64)
    }
}
impl From<&str> for Value {
    fn from(x: &str) -> Self {
        Value::Str(x.to_string())
    }
}
impl From<String> for Value {
    fn from(x: String) -> Self {
        Value::Str(x)
    }
}
impl From<bool> for Value {
    fn from(x: bool) -> Self {
        Value::Bool(x)
    }
}
impl<T: Into<Value>> From<Vec<T>> for Value {
    fn from(xs: Vec<T>) -> Self {
        Value::Arr(xs.into_iter().map(Into::into).collect())
    }
}

/// Build an object value: `obj(&[("k", v.into()), ...])`.
pub fn obj(kvs: &[(&str, Value)]) -> Value {
    Value::Obj(kvs.iter().map(|(k, v)| (k.to_string(), v.clone())).collect())
}

/// Map form used by config code.
pub fn to_map(v: &Value) -> BTreeMap<String, Value> {
    match v {
        Value::Obj(kvs) => kvs.iter().cloned().collect(),
        _ => BTreeMap::new(),
    }
}

struct Parser<'a> {
    bytes: &'a [u8],
    pos: usize,
}

impl<'a> Parser<'a> {
    fn skip_ws(&mut self) {
        while self.pos < self.bytes.len()
            && matches!(self.bytes[self.pos], b' ' | b'\t' | b'\n' | b'\r')
        {
            self.pos += 1;
        }
    }

    fn peek(&self) -> Option<u8> {
        self.bytes.get(self.pos).copied()
    }

    fn expect(&mut self, b: u8) -> Result<(), JsonError> {
        match self.peek() {
            Some(c) if c == b => {
                self.pos += 1;
                Ok(())
            }
            Some(c) => Err(JsonError::Unexpected(c as char, self.pos)),
            None => Err(JsonError::Eof(self.pos)),
        }
    }

    fn value(&mut self) -> Result<Value, JsonError> {
        match self.peek().ok_or(JsonError::Eof(self.pos))? {
            b'{' => self.object(),
            b'[' => self.array(),
            b'"' => Ok(Value::Str(self.string()?)),
            b't' => self.keyword("true", Value::Bool(true)),
            b'f' => self.keyword("false", Value::Bool(false)),
            b'n' => self.keyword("null", Value::Null),
            b'-' | b'0'..=b'9' => self.number(),
            c => Err(JsonError::Unexpected(c as char, self.pos)),
        }
    }

    fn keyword(&mut self, kw: &str, v: Value) -> Result<Value, JsonError> {
        if self.bytes[self.pos..].starts_with(kw.as_bytes()) {
            self.pos += kw.len();
            Ok(v)
        } else {
            Err(JsonError::Unexpected(
                self.bytes[self.pos] as char,
                self.pos,
            ))
        }
    }

    fn number(&mut self) -> Result<Value, JsonError> {
        let start = self.pos;
        if self.peek() == Some(b'-') {
            self.pos += 1;
        }
        while matches!(self.peek(), Some(b'0'..=b'9')) {
            self.pos += 1;
        }
        if self.peek() == Some(b'.') {
            self.pos += 1;
            while matches!(self.peek(), Some(b'0'..=b'9')) {
                self.pos += 1;
            }
        }
        if matches!(self.peek(), Some(b'e' | b'E')) {
            self.pos += 1;
            if matches!(self.peek(), Some(b'+' | b'-')) {
                self.pos += 1;
            }
            while matches!(self.peek(), Some(b'0'..=b'9')) {
                self.pos += 1;
            }
        }
        std::str::from_utf8(&self.bytes[start..self.pos])
            .ok()
            .and_then(|s| s.parse::<f64>().ok())
            .map(Value::Num)
            .ok_or(JsonError::BadNumber(start))
    }

    fn string(&mut self) -> Result<String, JsonError> {
        self.expect(b'"')?;
        let mut out = String::new();
        loop {
            match self.peek().ok_or(JsonError::Eof(self.pos))? {
                b'"' => {
                    self.pos += 1;
                    return Ok(out);
                }
                b'\\' => {
                    self.pos += 1;
                    let e = self.peek().ok_or(JsonError::Eof(self.pos))?;
                    self.pos += 1;
                    match e {
                        b'"' => out.push('"'),
                        b'\\' => out.push('\\'),
                        b'/' => out.push('/'),
                        b'b' => out.push('\u{8}'),
                        b'f' => out.push('\u{c}'),
                        b'n' => out.push('\n'),
                        b'r' => out.push('\r'),
                        b't' => out.push('\t'),
                        b'u' => {
                            let hex = self
                                .bytes
                                .get(self.pos..self.pos + 4)
                                .ok_or(JsonError::Eof(self.pos))?;
                            let code = u32::from_str_radix(
                                std::str::from_utf8(hex).map_err(|_| {
                                    JsonError::BadEscape('u', self.pos)
                                })?,
                                16,
                            )
                            .map_err(|_| JsonError::BadEscape('u', self.pos))?;
                            self.pos += 4;
                            // Surrogate pairs: parse low half if present.
                            let ch = if (0xD800..0xDC00).contains(&code) {
                                if self.bytes.get(self.pos) == Some(&b'\\')
                                    && self.bytes.get(self.pos + 1) == Some(&b'u')
                                {
                                    let hex2 = self
                                        .bytes
                                        .get(self.pos + 2..self.pos + 6)
                                        .ok_or(JsonError::Eof(self.pos))?;
                                    let low = u32::from_str_radix(
                                        std::str::from_utf8(hex2).map_err(
                                            |_| JsonError::BadEscape('u', self.pos),
                                        )?,
                                        16,
                                    )
                                    .map_err(|_| {
                                        JsonError::BadEscape('u', self.pos)
                                    })?;
                                    self.pos += 6;
                                    0x10000
                                        + ((code - 0xD800) << 10)
                                        + (low - 0xDC00)
                                } else {
                                    0xFFFD
                                }
                            } else {
                                code
                            };
                            out.push(char::from_u32(ch).unwrap_or('\u{FFFD}'));
                        }
                        c => return Err(JsonError::BadEscape(c as char, self.pos)),
                    }
                }
                _ => {
                    // Consume one UTF-8 scalar.
                    let s = std::str::from_utf8(&self.bytes[self.pos..])
                        .map_err(|_| JsonError::Unexpected('?', self.pos))?;
                    let c = s.chars().next().ok_or(JsonError::Eof(self.pos))?;
                    out.push(c);
                    self.pos += c.len_utf8();
                }
            }
        }
    }

    fn array(&mut self) -> Result<Value, JsonError> {
        self.expect(b'[')?;
        let mut xs = Vec::new();
        self.skip_ws();
        if self.peek() == Some(b']') {
            self.pos += 1;
            return Ok(Value::Arr(xs));
        }
        loop {
            self.skip_ws();
            xs.push(self.value()?);
            self.skip_ws();
            match self.peek() {
                Some(b',') => self.pos += 1,
                Some(b']') => {
                    self.pos += 1;
                    return Ok(Value::Arr(xs));
                }
                Some(c) => return Err(JsonError::Unexpected(c as char, self.pos)),
                None => return Err(JsonError::Eof(self.pos)),
            }
        }
    }

    fn object(&mut self) -> Result<Value, JsonError> {
        self.expect(b'{')?;
        let mut kvs = Vec::new();
        self.skip_ws();
        if self.peek() == Some(b'}') {
            self.pos += 1;
            return Ok(Value::Obj(kvs));
        }
        loop {
            self.skip_ws();
            let k = self.string()?;
            self.skip_ws();
            self.expect(b':')?;
            self.skip_ws();
            let v = self.value()?;
            kvs.push((k, v));
            self.skip_ws();
            match self.peek() {
                Some(b',') => self.pos += 1,
                Some(b'}') => {
                    self.pos += 1;
                    return Ok(Value::Obj(kvs));
                }
                Some(c) => return Err(JsonError::Unexpected(c as char, self.pos)),
                None => return Err(JsonError::Eof(self.pos)),
            }
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn parse_scalars() {
        assert_eq!(Value::parse("null").unwrap(), Value::Null);
        assert_eq!(Value::parse("true").unwrap(), Value::Bool(true));
        assert_eq!(Value::parse("-3.5e2").unwrap(), Value::Num(-350.0));
        assert_eq!(
            Value::parse("\"a\\nb\"").unwrap(),
            Value::Str("a\nb".into())
        );
    }

    #[test]
    fn parse_nested() {
        let v = Value::parse(r#"{"a": [1, 2, {"b": "x"}], "c": null}"#).unwrap();
        assert_eq!(v.path("a").unwrap().as_arr().unwrap().len(), 3);
        assert_eq!(
            v.get("a").unwrap().idx(2).unwrap().get("b").unwrap().as_str(),
            Some("x")
        );
        assert_eq!(v.get("c"), Some(&Value::Null));
    }

    #[test]
    fn roundtrip() {
        let src = r#"{"name":"k","shape":[3,4],"flops":1024,"nested":{"x":true,"y":[1.5,-2]}}"#;
        let v = Value::parse(src).unwrap();
        let v2 = Value::parse(&v.to_string()).unwrap();
        assert_eq!(v, v2);
    }

    #[test]
    fn unicode_escapes() {
        let v = Value::parse(r#""é😀""#).unwrap();
        assert_eq!(v.as_str(), Some("é😀"));
    }

    #[test]
    fn rejects_garbage() {
        assert!(Value::parse("{").is_err());
        assert!(Value::parse("[1,]").is_err());
        assert!(Value::parse("1 2").is_err());
        assert!(Value::parse("{\"a\" 1}").is_err());
    }

    #[test]
    fn integer_formatting() {
        assert_eq!(Value::Num(42.0).to_string(), "42");
        assert_eq!(Value::Num(0.5).to_string(), "0.5");
    }

    #[test]
    fn obj_builder_and_path() {
        let v = obj(&[
            ("a", obj(&[("b", Value::from(7u64))])),
            ("s", Value::from("hi")),
        ]);
        assert_eq!(v.path("a.b").unwrap().as_u64(), Some(7));
        assert_eq!(v.path("s").unwrap().as_str(), Some("hi"));
        assert!(v.path("a.z").is_none());
    }

    #[test]
    fn preserves_key_order() {
        let v = Value::parse(r#"{"z":1,"a":2,"m":3}"#).unwrap();
        let keys: Vec<&str> =
            v.as_obj().unwrap().iter().map(|(k, _)| k.as_str()).collect();
        assert_eq!(keys, ["z", "a", "m"]);
    }
}
