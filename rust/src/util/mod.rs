//! Dependency-free utility substrate: PRNGs, statistics, JSON, ring
//! buffers, and a mini property-testing framework.
//!
//! The offline crate set has no rand/serde/proptest, so these are built
//! in-repo and unit-tested against published reference values where they
//! exist (PCG32, SplitMix64).

pub mod json;
pub mod prng;
pub mod quickcheck;
pub mod ring;
pub mod stats;

pub use json::Value as Json;
pub use prng::{Pcg32, SplitMix64};
pub use ring::Ring;
pub use stats::Summary;
