//! Mini property-testing framework (proptest is not in the offline set).
//!
//! Deterministic, seed-reported, with linear input shrinking for integer
//! vectors. Usage:
//!
//! ```ignore
//! forall(200, |g| {
//!     let xs = g.vec_u32(0..1000, 0..64);
//!     let mut sorted = xs.clone();
//!     sorted.sort_unstable();
//!     prop_assert(is_sorted(&sorted), "sort postcondition")
//! });
//! ```
//!
//! On failure the panic message carries the case seed so the exact input
//! can be replayed with `replay(seed, |g| ...)`.

use crate::util::prng::Pcg32;
use std::ops::Range;

pub struct Gen {
    rng: Pcg32,
    pub seed: u64,
}

impl Gen {
    pub fn new(seed: u64) -> Self {
        Self {
            rng: Pcg32::seeded(seed),
            seed,
        }
    }

    pub fn u32(&mut self, range: Range<u32>) -> u32 {
        assert!(range.end > range.start);
        range.start + self.rng.next_below(range.end - range.start)
    }

    pub fn u64(&mut self, range: Range<u64>) -> u64 {
        assert!(range.end > range.start);
        range.start + self.rng.next_u64() % (range.end - range.start)
    }

    pub fn usize(&mut self, range: Range<usize>) -> usize {
        self.u64(range.start as u64..range.end as u64) as usize
    }

    pub fn i64(&mut self, range: Range<i64>) -> i64 {
        let span = (range.end - range.start) as u64;
        range.start + (self.rng.next_u64() % span) as i64
    }

    pub fn f64(&mut self, range: Range<f64>) -> f64 {
        range.start + self.rng.next_f64() * (range.end - range.start)
    }

    pub fn bool(&mut self) -> bool {
        self.rng.chance(0.5)
    }

    pub fn chance(&mut self, p: f64) -> bool {
        self.rng.chance(p)
    }

    pub fn vec_u32(&mut self, val: Range<u32>, len: Range<usize>) -> Vec<u32> {
        let n = self.usize(len);
        (0..n).map(|_| self.u32(val.clone())).collect()
    }

    pub fn vec_f64(&mut self, val: Range<f64>, len: Range<usize>) -> Vec<f64> {
        let n = self.usize(len);
        (0..n).map(|_| self.f64(val.clone())).collect()
    }

    pub fn pick<'a, T>(&mut self, xs: &'a [T]) -> &'a T {
        &xs[self.rng.index(xs.len())]
    }

    pub fn rng(&mut self) -> &mut Pcg32 {
        &mut self.rng
    }
}

/// Outcome of one property case.
pub type PropResult = Result<(), String>;

pub fn prop_assert(cond: bool, msg: &str) -> PropResult {
    if cond {
        Ok(())
    } else {
        Err(msg.to_string())
    }
}

pub fn prop_assert_eq<T: PartialEq + std::fmt::Debug>(a: T, b: T) -> PropResult {
    if a == b {
        Ok(())
    } else {
        Err(format!("expected {a:?} == {b:?}"))
    }
}

/// Approximate float equality for simulator invariants.
pub fn prop_close(a: f64, b: f64, tol: f64) -> PropResult {
    if (a - b).abs() <= tol * (1.0 + a.abs().max(b.abs())) {
        Ok(())
    } else {
        Err(format!("expected {a} ≈ {b} (tol {tol})"))
    }
}

/// Run `cases` random cases of `prop`; panics with the case seed on the
/// first failure. The master seed is env-overridable (RLARCH_PROP_SEED)
/// so CI failures are replayable.
pub fn forall<F>(cases: u32, prop: F)
where
    F: Fn(&mut Gen) -> PropResult,
{
    let master = std::env::var("RLARCH_PROP_SEED")
        .ok()
        .and_then(|s| s.parse().ok())
        .unwrap_or(0x5EED_2020_u64);
    let mut root = Pcg32::seeded(master);
    for case in 0..cases {
        let seed = root.next_u64() ^ case as u64;
        let mut g = Gen::new(seed);
        if let Err(msg) = prop(&mut g) {
            panic!(
                "property failed on case {case} (seed {seed}): {msg}\n\
                 replay with util::quickcheck::replay({seed}, ...)"
            );
        }
    }
}

/// Re-run a single failing case by seed.
pub fn replay<F>(seed: u64, prop: F)
where
    F: Fn(&mut Gen) -> PropResult,
{
    let mut g = Gen::new(seed);
    if let Err(msg) = prop(&mut g) {
        panic!("replay(seed {seed}) failed: {msg}");
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn forall_passes_trivial_property() {
        forall(100, |g| {
            let x = g.u32(0..100);
            prop_assert(x < 100, "range upper bound")
        });
    }

    #[test]
    #[should_panic(expected = "property failed")]
    fn forall_reports_failure_with_seed() {
        forall(50, |g| {
            let x = g.u32(0..10);
            prop_assert(x < 9, "will eventually fail")
        });
    }

    #[test]
    fn gen_is_deterministic_per_seed() {
        let mut a = Gen::new(42);
        let mut b = Gen::new(42);
        for _ in 0..32 {
            assert_eq!(a.u64(0..1_000_000), b.u64(0..1_000_000));
        }
    }

    #[test]
    fn vec_len_respects_range() {
        let mut g = Gen::new(7);
        for _ in 0..100 {
            let v = g.vec_u32(0..5, 2..6);
            assert!((2..6).contains(&v.len()));
            assert!(v.iter().all(|x| *x < 5));
        }
    }

    #[test]
    fn prop_close_tolerance() {
        assert!(prop_close(1.0, 1.0 + 1e-12, 1e-9).is_ok());
        assert!(prop_close(1.0, 1.1, 1e-9).is_err());
    }
}
