//! Fixed-capacity ring buffer used by metrics windows and trajectory
//! accumulation (keeps the hot path allocation-free).

#[derive(Clone, Debug)]
pub struct Ring<T> {
    buf: Vec<T>,
    head: usize, // next write position
    len: usize,
    cap: usize,
}

impl<T: Clone> Ring<T> {
    pub fn new(cap: usize) -> Self {
        assert!(cap > 0);
        Self {
            buf: Vec::with_capacity(cap),
            head: 0,
            len: 0,
            cap,
        }
    }

    pub fn capacity(&self) -> usize {
        self.cap
    }

    pub fn len(&self) -> usize {
        self.len
    }

    pub fn is_empty(&self) -> bool {
        self.len == 0
    }

    pub fn is_full(&self) -> bool {
        self.len == self.cap
    }

    /// Push, overwriting the oldest element when full. Returns the evicted
    /// element if any.
    pub fn push(&mut self, x: T) -> Option<T> {
        if self.buf.len() < self.cap {
            self.buf.push(x);
            self.head = (self.head + 1) % self.cap;
            self.len += 1;
            None
        } else {
            let old = std::mem::replace(&mut self.buf[self.head], x);
            self.head = (self.head + 1) % self.cap;
            if self.len < self.cap {
                self.len += 1;
                None
            } else {
                Some(old)
            }
        }
    }

    /// Oldest-first iteration.
    pub fn iter(&self) -> impl Iterator<Item = &T> {
        let start = if self.len < self.cap { 0 } else { self.head };
        (0..self.len).map(move |i| &self.buf[(start + i) % self.buf.len().max(1)])
    }

    /// Most recent element.
    pub fn last(&self) -> Option<&T> {
        if self.len == 0 {
            None
        } else {
            let idx = (self.head + self.cap - 1) % self.cap;
            self.buf.get(idx.min(self.buf.len() - 1))
        }
    }

    pub fn clear(&mut self) {
        self.buf.clear();
        self.head = 0;
        self.len = 0;
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn fills_then_overwrites() {
        let mut r = Ring::new(3);
        assert_eq!(r.push(1), None);
        assert_eq!(r.push(2), None);
        assert_eq!(r.push(3), None);
        assert!(r.is_full());
        assert_eq!(r.push(4), Some(1));
        assert_eq!(r.iter().copied().collect::<Vec<_>>(), vec![2, 3, 4]);
    }

    #[test]
    fn last_tracks_most_recent() {
        let mut r = Ring::new(2);
        assert_eq!(r.last(), None);
        r.push(10);
        assert_eq!(r.last(), Some(&10));
        r.push(20);
        r.push(30);
        assert_eq!(r.last(), Some(&30));
    }

    #[test]
    fn iter_order_before_full() {
        let mut r = Ring::new(5);
        r.push("a");
        r.push("b");
        assert_eq!(r.iter().copied().collect::<Vec<_>>(), vec!["a", "b"]);
    }

    #[test]
    fn clear_resets() {
        let mut r = Ring::new(2);
        r.push(1);
        r.push(2);
        r.clear();
        assert!(r.is_empty());
        r.push(9);
        assert_eq!(r.iter().copied().collect::<Vec<_>>(), vec![9]);
    }
}
