//! Summary statistics used by metrics, benches, and the simulator reports.

/// Online mean/variance (Welford) plus min/max.
#[derive(Clone, Debug, Default)]
pub struct Summary {
    n: u64,
    mean: f64,
    m2: f64,
    min: f64,
    max: f64,
}

impl Summary {
    pub fn new() -> Self {
        Self {
            n: 0,
            mean: 0.0,
            m2: 0.0,
            min: f64::INFINITY,
            max: f64::NEG_INFINITY,
        }
    }

    pub fn add(&mut self, x: f64) {
        self.n += 1;
        let d = x - self.mean;
        self.mean += d / self.n as f64;
        self.m2 += d * (x - self.mean);
        self.min = self.min.min(x);
        self.max = self.max.max(x);
    }

    pub fn count(&self) -> u64 {
        self.n
    }

    /// Total of all recorded values (`mean * n`). Welford tracks the
    /// running mean, so the sum is reconstructed; exact up to fp
    /// rounding, which is what offline rate derivation needs.
    pub fn sum(&self) -> f64 {
        if self.n == 0 {
            0.0
        } else {
            self.mean * self.n as f64
        }
    }

    pub fn mean(&self) -> f64 {
        if self.n == 0 {
            f64::NAN
        } else {
            self.mean
        }
    }

    pub fn variance(&self) -> f64 {
        if self.n < 2 {
            0.0
        } else {
            self.m2 / (self.n - 1) as f64
        }
    }

    pub fn std(&self) -> f64 {
        self.variance().sqrt()
    }

    pub fn min(&self) -> f64 {
        self.min
    }

    pub fn max(&self) -> f64 {
        self.max
    }

    pub fn merge(&mut self, other: &Summary) {
        if other.n == 0 {
            return;
        }
        if self.n == 0 {
            *self = other.clone();
            return;
        }
        let n = (self.n + other.n) as f64;
        let d = other.mean - self.mean;
        let mean = self.mean + d * other.n as f64 / n;
        self.m2 += other.m2 + d * d * self.n as f64 * other.n as f64 / n;
        self.mean = mean;
        self.n += other.n;
        self.min = self.min.min(other.min);
        self.max = self.max.max(other.max);
    }
}

/// Percentile of a sample set (interpolated, like numpy's "linear").
/// Sorts a copy; fine for bench-sized samples.
pub fn percentile(samples: &[f64], p: f64) -> f64 {
    if samples.is_empty() {
        return f64::NAN;
    }
    let mut xs: Vec<f64> = samples.to_vec();
    xs.sort_by(|a, b| a.partial_cmp(b).unwrap());
    let rank = (p / 100.0) * (xs.len() - 1) as f64;
    let lo = rank.floor() as usize;
    let hi = rank.ceil() as usize;
    if lo == hi {
        xs[lo]
    } else {
        let frac = rank - lo as f64;
        xs[lo] * (1.0 - frac) + xs[hi] * frac
    }
}

pub fn mean(samples: &[f64]) -> f64 {
    if samples.is_empty() {
        return f64::NAN;
    }
    samples.iter().sum::<f64>() / samples.len() as f64
}

/// Geometric mean (used for slowdown aggregation across workloads).
pub fn geomean(samples: &[f64]) -> f64 {
    if samples.is_empty() {
        return f64::NAN;
    }
    let log_sum: f64 = samples.iter().map(|x| x.max(1e-300).ln()).sum();
    (log_sum / samples.len() as f64).exp()
}

/// Fixed-bucket histogram for latency-style metrics (log-spaced buckets).
#[derive(Clone, Debug)]
pub struct LogHistogram {
    /// Bucket upper bounds (exclusive), ascending; final bucket = overflow.
    bounds: Vec<f64>,
    counts: Vec<u64>,
    total: u64,
}

impl LogHistogram {
    /// Buckets from `lo` to `hi` with `per_decade` log-spaced buckets.
    pub fn new(lo: f64, hi: f64, per_decade: usize) -> Self {
        assert!(lo > 0.0 && hi > lo && per_decade > 0);
        let decades = (hi / lo).log10();
        let n = (decades * per_decade as f64).ceil() as usize + 1;
        let step = (hi / lo).powf(1.0 / (n - 1) as f64);
        let mut bounds = Vec::with_capacity(n);
        let mut b = lo;
        for _ in 0..n {
            bounds.push(b);
            b *= step;
        }
        let len = bounds.len();
        Self {
            bounds,
            counts: vec![0; len + 1],
            total: 0,
        }
    }

    pub fn add(&mut self, x: f64) {
        let idx = match self
            .bounds
            .binary_search_by(|b| b.partial_cmp(&x).unwrap())
        {
            Ok(i) => i + 1,
            Err(i) => i,
        };
        self.counts[idx] += 1;
        self.total += 1;
    }

    pub fn total(&self) -> u64 {
        self.total
    }

    /// Approximate quantile from the bucket boundaries.
    pub fn quantile(&self, q: f64) -> f64 {
        if self.total == 0 {
            return f64::NAN;
        }
        let target = (q * self.total as f64).ceil() as u64;
        let mut seen = 0;
        for (i, c) in self.counts.iter().enumerate() {
            seen += c;
            if seen >= target {
                return if i == 0 {
                    self.bounds[0]
                } else if i >= self.bounds.len() {
                    *self.bounds.last().unwrap()
                } else {
                    self.bounds[i]
                };
            }
        }
        *self.bounds.last().unwrap()
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn summary_mean_std() {
        let mut s = Summary::new();
        for x in [2.0, 4.0, 4.0, 4.0, 5.0, 5.0, 7.0, 9.0] {
            s.add(x);
        }
        assert!((s.mean() - 5.0).abs() < 1e-12);
        assert!((s.std() - 2.138089935299395).abs() < 1e-9);
        assert_eq!(s.min(), 2.0);
        assert_eq!(s.max(), 9.0);
        assert!((s.sum() - 40.0).abs() < 1e-9);
        assert_eq!(Summary::new().sum(), 0.0);
    }

    #[test]
    fn summary_merge_equals_combined() {
        let xs: Vec<f64> = (0..100).map(|i| (i as f64).sin() * 3.0).collect();
        let mut a = Summary::new();
        let mut b = Summary::new();
        let mut all = Summary::new();
        for (i, x) in xs.iter().enumerate() {
            if i % 2 == 0 {
                a.add(*x);
            } else {
                b.add(*x);
            }
            all.add(*x);
        }
        a.merge(&b);
        assert_eq!(a.count(), all.count());
        assert!((a.mean() - all.mean()).abs() < 1e-12);
        assert!((a.variance() - all.variance()).abs() < 1e-9);
    }

    #[test]
    fn percentile_linear_interp() {
        let xs = [1.0, 2.0, 3.0, 4.0];
        assert_eq!(percentile(&xs, 0.0), 1.0);
        assert_eq!(percentile(&xs, 100.0), 4.0);
        assert!((percentile(&xs, 50.0) - 2.5).abs() < 1e-12);
    }

    #[test]
    fn geomean_of_ratios() {
        let xs = [2.0, 8.0];
        assert!((geomean(&xs) - 4.0).abs() < 1e-12);
    }

    #[test]
    fn histogram_quantiles_monotone() {
        let mut h = LogHistogram::new(1e-6, 1.0, 10);
        let mut rng = crate::util::prng::Pcg32::seeded(1);
        for _ in 0..10_000 {
            h.add(10f64.powf(-6.0 * rng.next_f64()));
        }
        let q50 = h.quantile(0.5);
        let q99 = h.quantile(0.99);
        assert!(q50 <= q99);
        assert_eq!(h.total(), 10_000);
    }

    #[test]
    fn empty_stats_are_nan() {
        assert!(mean(&[]).is_nan());
        assert!(percentile(&[], 50.0).is_nan());
        assert!(Summary::new().mean().is_nan());
    }
}
