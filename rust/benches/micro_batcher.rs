//! Micro-bench + ablation A2: inference batcher policy surface, and the
//! zero-allocation gate on the pooled central path.
//!
//! Three sections:
//!
//! 1. **Policy sweep** — (max_batch, timeout) against a mock backend
//!    with a fixed per-call latency, measuring aggregate actor
//!    throughput and mean batch occupancy — the trade-off behind the
//!    paper's central-inference design.
//! 2. **Bucket ladders** — the padded-AOT launch policy
//!    (`batcher.batch_sizes`): padding efficiency (real rows / launched
//!    rows) per ladder, feeding the EXPERIMENTS.md occupancy table.
//! 3. **Zero-allocation gate** — a counting global allocator around the
//!    pooled `CentralClient` round-trip (recycled input slabs,
//!    persistent mailbox, shared output slabs). The acceptance bar
//!    (ISSUE 5) is **zero steady-state allocations per central
//!    inference round-trip**; the bench hard-asserts it, so the CI
//!    `--quick` smoke run enforces the property rather than just
//!    reporting it — the central-path sibling of `micro_trajectory`'s
//!    transition gate.
//!
//! `--quick` shrinks every loop (the CI smoke run).

use rlarch::config::BatcherConfig;
use rlarch::coordinator::Batcher;
use rlarch::metrics::Registry;
use rlarch::policy::{CentralClient, PolicyClient};
use rlarch::report::figure::Table;
use rlarch::report::write_csv;
use rlarch::runtime::{Backend, MockModel, ModelDims};
use std::alloc::{GlobalAlloc, Layout, System};
use std::sync::atomic::{AtomicU64, Ordering};
use std::sync::Arc;
use std::time::{Duration, Instant};

/// Counts every allocator entry (alloc + realloc); frees are not
/// interesting here. The counter is what makes "zero-allocation"
/// checkable instead of inferred from timings. Process-wide: the
/// batcher thread's side of the round-trip is measured too.
struct CountingAlloc;

static ALLOC_CALLS: AtomicU64 = AtomicU64::new(0);

unsafe impl GlobalAlloc for CountingAlloc {
    unsafe fn alloc(&self, layout: Layout) -> *mut u8 {
        ALLOC_CALLS.fetch_add(1, Ordering::Relaxed);
        System.alloc(layout)
    }

    unsafe fn dealloc(&self, ptr: *mut u8, layout: Layout) {
        System.dealloc(ptr, layout)
    }

    unsafe fn realloc(&self, ptr: *mut u8, layout: Layout, new_size: usize) -> *mut u8 {
        ALLOC_CALLS.fetch_add(1, Ordering::Relaxed);
        System.realloc(ptr, layout, new_size)
    }
}

#[global_allocator]
static GLOBAL: CountingAlloc = CountingAlloc;

fn alloc_calls() -> u64 {
    ALLOC_CALLS.load(Ordering::Relaxed)
}

fn bench_dims() -> ModelDims {
    ModelDims {
        obs_len: 64,
        hidden: 16,
        num_actions: 4,
        seq_len: 8,
        train_batch: 4,
    }
}

fn run_policy(max_batch: usize, timeout_us: u64, actors: usize, per_actor: usize) -> (f64, f64) {
    let dims = bench_dims();
    let backend = Backend::Mock(Arc::new(
        MockModel::new(dims, 9).with_infer_latency(Duration::from_micros(150)),
    ));
    let metrics = Registry::new();
    let cfg = BatcherConfig {
        max_batch,
        timeout_us,
        batch_sizes: vec![max_batch],
    };
    let (batcher, handle) = Batcher::spawn(cfg, backend, metrics.clone());
    let t0 = Instant::now();
    std::thread::scope(|s| {
        for a in 0..actors {
            let h = handle.clone();
            s.spawn(move || {
                for _ in 0..per_actor {
                    h.infer(a, vec![0.3; 64], vec![0.0; 16], vec![0.0; 16])
                        .unwrap();
                }
            });
        }
    });
    let elapsed = t0.elapsed().as_secs_f64();
    drop(handle);
    batcher.join();
    let items = metrics.counter("batcher.items").get();
    let batches = metrics.counter("batcher.batches").get().max(1);
    (items as f64 / elapsed, items as f64 / batches as f64)
}

/// Drive `actors` single-row submitters through a bucket ladder and
/// report (mean occupancy, padding efficiency = real rows / launched
/// rows). Efficiency is counter-based (`batcher.items` vs
/// `batcher.padded_rows`), so the number is structural, not timing
/// noise.
fn run_buckets(
    batch_sizes: Vec<usize>,
    actors: usize,
    per_actor: usize,
) -> (f64, f64) {
    let dims = bench_dims();
    let backend = Backend::Mock(Arc::new(
        MockModel::new(dims, 9).with_infer_latency(Duration::from_micros(150)),
    ));
    let metrics = Registry::new();
    let cfg = BatcherConfig {
        max_batch: *batch_sizes.last().unwrap(),
        timeout_us: 500,
        batch_sizes,
    };
    let (batcher, handle) = Batcher::spawn(cfg, backend, metrics.clone());
    std::thread::scope(|s| {
        for a in 0..actors {
            let h = handle.clone();
            s.spawn(move || {
                for _ in 0..per_actor {
                    h.infer(a, vec![0.3; 64], vec![0.0; 16], vec![0.0; 16])
                        .unwrap();
                }
            });
        }
    });
    drop(handle);
    batcher.join();
    let items = metrics.counter("batcher.items").get();
    let padded = metrics.counter("batcher.padded_rows").get();
    let batches = metrics.counter("batcher.batches").get().max(1);
    (
        items as f64 / batches as f64,
        items as f64 / (items + padded).max(1) as f64,
    )
}

/// The gate: allocator entries across `iters` pooled central
/// round-trips after `warmup` round-trips of pool/queue/slab warmup.
/// `rows` rides one ticket; buckets [4, 8] with cap 8 exercise both the
/// padded partial flush (rows < 8) and the oversized split (rows > 8).
fn roundtrip_allocs(rows: usize, warmup: usize, iters: usize) -> u64 {
    let dims = bench_dims();
    let backend = Backend::Mock(Arc::new(MockModel::new(dims, 9)));
    let metrics = Registry::new();
    let cfg = BatcherConfig {
        max_batch: 8,
        timeout_us: 50,
        batch_sizes: vec![4, 8],
    };
    let (batcher, handle) = Batcher::spawn(cfg, backend, metrics.clone());
    let mut client = CentralClient::new(handle.clone(), 0, dims, &metrics);
    let obs = vec![0.3f32; rows * dims.obs_len];
    let h_in = vec![0.0f32; rows * dims.hidden];
    let c_in = vec![0.0f32; rows * dims.hidden];
    let mut q = vec![0.0f32; rows * dims.num_actions];
    let mut h_out = vec![0.0f32; rows * dims.hidden];
    let mut c_out = vec![0.0f32; rows * dims.hidden];
    for _ in 0..warmup {
        client.submit(0, rows, &obs, &h_in, &c_in).unwrap();
        client.wait(0, &mut q, &mut h_out, &mut c_out).unwrap();
    }
    let a0 = alloc_calls();
    for _ in 0..iters {
        client.submit(0, rows, &obs, &h_in, &c_in).unwrap();
        client.wait(0, &mut q, &mut h_out, &mut c_out).unwrap();
    }
    let delta = alloc_calls() - a0;
    std::hint::black_box(&q);
    drop(client);
    drop(handle);
    batcher.join();
    delta
}

fn main() {
    let quick = std::env::args().any(|a| a == "--quick");
    let actors = 16;
    let per_actor = if quick { 40 } else { 300 };

    println!("# micro_batcher — batching policy sweep (mock backend, 150us/call)\n");
    let mut t = Table::new(&[
        "max_batch", "timeout us", "throughput steps/s", "mean occupancy",
    ]);
    let mut csv = String::from("max_batch,timeout_us,throughput,occupancy\n");
    for &mb in &[1usize, 4, 16, 64] {
        for &to in &[100u64, 500, 2_000] {
            let (thr, occ) = run_policy(mb, to, actors, per_actor);
            t.row(&[
                mb.to_string(),
                to.to_string(),
                format!("{thr:.0}"),
                format!("{occ:.2}"),
            ]);
            csv.push_str(&format!("{mb},{to},{thr},{occ}\n"));
        }
    }
    println!("{}", t.to_markdown());
    println!(
        "batching wins: max_batch=1 pays one 150us call per step; large \
         batches amortize it across all concurrently-pending actors.\n"
    );

    println!("# bucket ladders — padded-AOT launch policy (16 actors, cap 16)\n");
    let mut bt = Table::new(&["batch_sizes", "mean occupancy", "padding efficiency"]);
    let mut bcsv = String::from("batch_sizes,occupancy,efficiency\n");
    for ladder in [
        vec![16usize],
        vec![4, 16],
        vec![4, 8, 16],
        vec![1, 2, 4, 8, 16],
    ] {
        let label = format!("{ladder:?}");
        let (occ, eff) = run_buckets(ladder, actors, per_actor);
        bt.row(&[label.clone(), format!("{occ:.2}"), format!("{eff:.2}")]);
        bcsv.push_str(&format!("{},{occ},{eff}\n", label.replace(", ", "+")));
    }
    println!("{}", bt.to_markdown());
    println!(
        "the ladder trade: one bucket per cap ([16]) means one compiled \
         executable but every partial flush pads to 16 rows; denser \
         ladders cut the padding waste at the cost of more AOT shapes.\n"
    );

    // ---- the zero-allocation gate (hard requirement: 0) ----
    let gate_iters = if quick { 1_500 } else { 10_000 };
    println!("# zero-allocation gate — pooled central round-trip\n");
    let mut gt = Table::new(&["rows/submission", "round-trips", "allocs/round-trip"]);
    for rows in [3usize, 12] {
        let delta = roundtrip_allocs(rows, 300, gate_iters);
        gt.row(&[
            rows.to_string(),
            gate_iters.to_string(),
            format!("{:.4}", delta as f64 / gate_iters as f64),
        ]);
        assert_eq!(
            delta, 0,
            "the pooled central inference path must be allocation-free in \
             steady state ({rows} rows/submission: {delta} allocs over \
             {gate_iters} round-trips)"
        );
    }
    println!("{}", gt.to_markdown());
    println!(
        "hard-asserted 0 on both shapes: rows=3 exercises the padded \
         partial flush (bucket 4), rows=12 the oversized split (8 + 4) \
         with two chunks demuxed through one persistent mailbox."
    );
    let p = write_csv("micro_batcher", &csv);
    println!("csv: {}", p.display());
    let p = write_csv("micro_batcher_buckets", &bcsv);
    println!("csv: {}", p.display());
}
