//! Micro-bench + ablation A2: inference batcher policy surface.
//!
//! Sweeps (max_batch, timeout) against a mock backend with a fixed
//! per-call latency, measuring aggregate actor throughput and mean
//! batch occupancy — the policy trade-off behind the paper's central-
//! inference design.

use rlarch::config::BatcherConfig;
use rlarch::coordinator::Batcher;
use rlarch::metrics::Registry;
use rlarch::report::figure::Table;
use rlarch::report::write_csv;
use rlarch::runtime::{Backend, MockModel, ModelDims};
use std::sync::Arc;
use std::time::{Duration, Instant};

fn run_policy(max_batch: usize, timeout_us: u64, actors: usize, per_actor: usize) -> (f64, f64) {
    let dims = ModelDims {
        obs_len: 64,
        hidden: 16,
        num_actions: 4,
        seq_len: 8,
        train_batch: 4,
    };
    let backend = Backend::Mock(Arc::new(
        MockModel::new(dims, 9).with_infer_latency(Duration::from_micros(150)),
    ));
    let metrics = Registry::new();
    let cfg = BatcherConfig {
        max_batch,
        timeout_us,
        batch_sizes: vec![max_batch],
    };
    let (batcher, handle) = Batcher::spawn(cfg, backend, metrics.clone());
    let t0 = Instant::now();
    std::thread::scope(|s| {
        for a in 0..actors {
            let h = handle.clone();
            s.spawn(move || {
                for _ in 0..per_actor {
                    h.infer(a, vec![0.3; 64], vec![0.0; 16], vec![0.0; 16])
                        .unwrap();
                }
            });
        }
    });
    let elapsed = t0.elapsed().as_secs_f64();
    drop(handle);
    batcher.join();
    let items = metrics.counter("batcher.items").get();
    let batches = metrics.counter("batcher.batches").get().max(1);
    (items as f64 / elapsed, items as f64 / batches as f64)
}

fn main() {
    println!("# micro_batcher — batching policy sweep (mock backend, 150us/call)\n");
    let actors = 16;
    let per_actor = 300;
    let mut t = Table::new(&[
        "max_batch", "timeout us", "throughput steps/s", "mean occupancy",
    ]);
    let mut csv = String::from("max_batch,timeout_us,throughput,occupancy\n");
    for &mb in &[1usize, 4, 16, 64] {
        for &to in &[100u64, 500, 2_000] {
            let (thr, occ) = run_policy(mb, to, actors, per_actor);
            t.row(&[
                mb.to_string(),
                to.to_string(),
                format!("{thr:.0}"),
                format!("{occ:.2}"),
            ]);
            csv.push_str(&format!("{mb},{to},{thr},{occ}\n"));
        }
    }
    println!("{}", t.to_markdown());
    println!(
        "batching wins: max_batch=1 pays one 150us call per step; large \
         batches amortize it across all concurrently-pending actors."
    );
    let p = write_csv("micro_batcher", &csv);
    println!("csv: {}", p.display());
}
