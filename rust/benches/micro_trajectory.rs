//! Micro-bench: the actor→replay transition path.
//!
//! Measures the `SequenceBuilder` hot loop three ways — the seed's
//! owned-`Transition` path (three `to_vec` copies per step, fresh slab
//! buffers per emitted sequence), the arena path (`push_slices` +
//! `SequencePool` recycling), and the full pooled ingest path into a
//! live replay — with a counting global allocator so the result is
//! *allocations per transition*, not just wall time. The acceptance
//! bar (ISSUE 4) is zero steady-state allocations per transition on the
//! pooled builder path; the bench hard-asserts it, so the CI `--quick`
//! smoke run enforces the property rather than just reporting it.
//!
//! The tables here regenerate EXPERIMENTS.md §Perf (transition path).
//!
//! `--quick` shrinks every loop (the CI smoke run).

use rlarch::replay::{IngestQueue, ReplayConfig, SequenceReplay};
use rlarch::rl::{SequenceBuilder, SequencePool, Transition};
use rlarch::report::figure::Table;
use std::alloc::{GlobalAlloc, Layout, System};
use std::sync::atomic::{AtomicU64, Ordering};
use std::sync::Arc;
use std::time::Instant;

/// Counts every allocator entry (alloc + realloc); frees are not
/// interesting here. The counter is what makes "zero-allocation"
/// checkable instead of inferred from timings.
struct CountingAlloc;

static ALLOC_CALLS: AtomicU64 = AtomicU64::new(0);

unsafe impl GlobalAlloc for CountingAlloc {
    unsafe fn alloc(&self, layout: Layout) -> *mut u8 {
        ALLOC_CALLS.fetch_add(1, Ordering::Relaxed);
        System.alloc(layout)
    }

    unsafe fn dealloc(&self, ptr: *mut u8, layout: Layout) {
        System.dealloc(ptr, layout)
    }

    unsafe fn realloc(&self, ptr: *mut u8, layout: Layout, new_size: usize) -> *mut u8 {
        ALLOC_CALLS.fetch_add(1, Ordering::Relaxed);
        System.realloc(ptr, layout, new_size)
    }
}

#[global_allocator]
static GLOBAL: CountingAlloc = CountingAlloc;

fn alloc_calls() -> u64 {
    ALLOC_CALLS.load(Ordering::Relaxed)
}

/// AOT-default trajectory shape: obs 400 (20x20 frame-stack 4 omitted
/// for brevity — same byte volume), LSTM 128, sequences 20 with
/// overlap 10, an episode end every ~97 steps.
const OBS_LEN: usize = 400;
const HIDDEN: usize = 128;
const SEQ_LEN: usize = 20;
const OVERLAP: usize = 10;

fn discount_at(i: usize) -> f32 {
    if i % 97 == 96 {
        0.0
    } else {
        0.99
    }
}

struct PathResult {
    name: &'static str,
    steps: usize,
    allocs: u64,
    elapsed_s: f64,
    sequences: u64,
}

impl PathResult {
    fn allocs_per_step(&self) -> f64 {
        self.allocs as f64 / self.steps as f64
    }

    fn ns_per_step(&self) -> f64 {
        self.elapsed_s * 1e9 / self.steps as f64
    }
}

/// The seed path: every transition owns three freshly allocated row
/// copies, every emitted sequence allocates fresh slab buffers.
fn seed_path(steps: usize, obs: &[f32], h: &[f32], c: &[f32]) -> PathResult {
    let mut b = SequenceBuilder::new(SEQ_LEN, OVERLAP, OBS_LEN, HIDDEN, 0);
    // Warmup: let internal capacities settle (they don't matter here,
    // but keep the two paths symmetric).
    for i in 0..SEQ_LEN * 4 {
        let _ = b.push(Transition {
            obs: obs.to_vec(),
            action: i as i32,
            reward: 1.0,
            discount: discount_at(i),
            h: h.to_vec(),
            c: c.to_vec(),
        });
    }
    let mut sequences = 0u64;
    let a0 = alloc_calls();
    let t0 = Instant::now();
    for i in 0..steps {
        if let Some(s) = b.push(Transition {
            obs: obs.to_vec(),
            action: i as i32,
            reward: 1.0,
            discount: discount_at(i),
            h: h.to_vec(),
            c: c.to_vec(),
        }) {
            sequences += 1;
            std::hint::black_box(&s);
        }
    }
    PathResult {
        name: "seed push(Transition)",
        steps,
        allocs: alloc_calls() - a0,
        elapsed_s: t0.elapsed().as_secs_f64(),
        sequences,
    }
}

/// The arena path: borrowed rows in, pooled slabs out, every emitted
/// sequence recycled straight back (steady state: replay evictions and
/// learner releases play that role in the real system).
fn pooled_path(steps: usize, obs: &[f32], h: &[f32], c: &[f32]) -> PathResult {
    let pool = Arc::new(SequencePool::new());
    let mut b = SequenceBuilder::new(SEQ_LEN, OVERLAP, OBS_LEN, HIDDEN, 0)
        .with_pool(pool.clone());
    // Warmup primes the pool (first slabs are misses) and the free
    // list's capacity.
    for i in 0..SEQ_LEN * 4 {
        if let Some(s) =
            b.push_slices(obs, i as i32, 1.0, discount_at(i), h, c)
        {
            pool.put(s);
        }
    }
    let mut sequences = 0u64;
    let a0 = alloc_calls();
    let t0 = Instant::now();
    for i in 0..steps {
        if let Some(s) =
            b.push_slices(obs, i as i32, 1.0, discount_at(i), h, c)
        {
            sequences += 1;
            pool.put(std::hint::black_box(s));
        }
    }
    let result = PathResult {
        name: "arena push_slices + pool",
        steps,
        allocs: alloc_calls() - a0,
        elapsed_s: t0.elapsed().as_secs_f64(),
        sequences,
    };
    assert_eq!(
        result.allocs, 0,
        "the pooled builder path must be allocation-free in steady state"
    );
    result
}

/// The full pooled transition path into a live sharded replay: builder
/// → ingest queue → add_batch, evictions recycling into the pool. The
/// only remaining per-sequence allocation is the `Arc` header replay
/// wraps around each stored sequence.
fn ingest_path(
    steps: usize,
    insert_batch: usize,
    obs: &[f32],
    h: &[f32],
    c: &[f32],
) -> (PathResult, u64) {
    let pool = Arc::new(SequencePool::new());
    let replay = Arc::new(
        SequenceReplay::new(ReplayConfig {
            capacity: 256,
            shards: 4,
            ..Default::default()
        })
        .with_pool(pool.clone()),
    );
    let mut b = SequenceBuilder::new(SEQ_LEN, OVERLAP, OBS_LEN, HIDDEN, 0)
        .with_pool(pool.clone());
    let mut q = IngestQueue::new(replay.clone(), insert_batch);
    // Warmup fills the ring so steady state is pure eviction/recycle.
    for i in 0..SEQ_LEN * 300 {
        if let Some(s) =
            b.push_slices(obs, i as i32, 1.0, discount_at(i), h, c)
        {
            q.push(s);
        }
    }
    q.flush();
    let mut sequences = 0u64;
    let locks0 = replay.lock_acquisitions();
    let a0 = alloc_calls();
    let t0 = Instant::now();
    for i in 0..steps {
        if let Some(s) =
            b.push_slices(obs, i as i32, 1.0, discount_at(i), h, c)
        {
            sequences += 1;
            q.push(s);
        }
    }
    q.flush();
    let result = PathResult {
        name: "arena + ingest into replay",
        steps,
        allocs: alloc_calls() - a0,
        elapsed_s: t0.elapsed().as_secs_f64(),
        sequences,
    };
    (result, replay.lock_acquisitions() - locks0)
}

fn main() {
    let quick = std::env::args().any(|a| a == "--quick");
    let steps = if quick { 40_000 } else { 400_000 };
    println!(
        "# micro_trajectory — transition path (obs {OBS_LEN}, H={HIDDEN}, \
         T={SEQ_LEN}/{OVERLAP})\n"
    );

    let obs = vec![0.5f32; OBS_LEN];
    let h = vec![0.1f32; HIDDEN];
    let c = vec![-0.1f32; HIDDEN];

    let seed = seed_path(steps, &obs, &h, &c);
    let pooled = pooled_path(steps, &obs, &h, &c);
    let (ingest, ingest_locks) = ingest_path(steps, 8, &obs, &h, &c);

    let mut t = Table::new(&[
        "path",
        "steps",
        "sequences",
        "allocs/transition",
        "ns/transition",
    ]);
    let mut csv = String::from("path,steps,sequences,allocs_per_step,ns_per_step\n");
    for r in [&seed, &pooled, &ingest] {
        t.row(&[
            r.name.to_string(),
            r.steps.to_string(),
            r.sequences.to_string(),
            format!("{:.4}", r.allocs_per_step()),
            format!("{:.0}", r.ns_per_step()),
        ]);
        csv.push_str(&format!(
            "{},{},{},{},{}\n",
            r.name,
            r.steps,
            r.sequences,
            r.allocs_per_step(),
            r.ns_per_step()
        ));
    }
    println!("{}", t.to_markdown());
    println!(
        "pooled path steady-state allocations per transition: {} (hard \
         requirement: 0)",
        pooled.allocs_per_step()
    );
    println!(
        "ingest path (insert_batch 8, 4 shards): {:.4} shard-lock \
         acquisitions per sequence\n",
        ingest_locks as f64 / ingest.sequences.max(1) as f64
    );
    let p = rlarch::report::write_csv("micro_trajectory", &csv);
    println!("csv: {}", p.display());
}
