//! Micro-bench: the fleet wire codec (DESIGN.md §14) — encode/decode
//! rates for submit, reply, and sequence frames at the shapes the
//! actor hot path actually ships, plus a counting-global-allocator
//! gate hard-asserting that steady-state encode and decode never enter
//! the allocator. Encoders reuse one `Vec<u8>` whose capacity settles;
//! decoders fill caller-provided `Vec<f32>`s — the property that makes
//! the socket path copy-light instead of malloc-bound.
//!
//! The throughput table feeds the transport bytes/s columns in
//! EXPERIMENTS.md §Perf.
//!
//! The fault-tolerance control plane (DESIGN.md §15) gets the same
//! treatment: the ping/pong codec and the heartbeat / liveness /
//! deadline state machines run once per quiet interval on *every*
//! connection, so their steady state is gated allocation-free too.
//!
//! So does the serving gate (DESIGN.md §16): admission decisions,
//! in-flight row accounting, and breaker feeding sit on every `Submit`
//! of every connection, and are gated allocation-free the same way.
//!
//! `--quick` shrinks every loop (the CI smoke run); the allocation
//! gates are asserted in both modes.

use rlarch::report::{bench, BenchResult};
use rlarch::rl::Sequence;
use rlarch::transport::frame::{
    decode_reply_ok, decode_sequence, decode_submit, encode_ping, encode_pong,
    encode_reply_ok, encode_sequence, encode_submit, parse_header, payload, FrameKind,
};
use rlarch::transport::{DeadlineEwma, Heartbeat, Liveness};
use std::alloc::{GlobalAlloc, Layout, System};
use std::sync::atomic::{AtomicU64, Ordering};
use std::time::{Duration, Instant};

/// Counts every allocator entry (alloc + realloc); frees are not
/// interesting here. Same gate pattern as `micro_env` /
/// `micro_trajectory`: the counter makes "zero-allocation" checkable
/// instead of inferred.
struct CountingAlloc;

static ALLOC_CALLS: AtomicU64 = AtomicU64::new(0);

unsafe impl GlobalAlloc for CountingAlloc {
    unsafe fn alloc(&self, layout: Layout) -> *mut u8 {
        ALLOC_CALLS.fetch_add(1, Ordering::Relaxed);
        System.alloc(layout)
    }

    unsafe fn dealloc(&self, ptr: *mut u8, layout: Layout) {
        System.dealloc(ptr, layout)
    }

    unsafe fn realloc(&self, ptr: *mut u8, layout: Layout, new_size: usize) -> *mut u8 {
        ALLOC_CALLS.fetch_add(1, Ordering::Relaxed);
        System.realloc(ptr, layout, new_size)
    }
}

#[global_allocator]
static GLOBAL: CountingAlloc = CountingAlloc;

fn alloc_calls() -> u64 {
    ALLOC_CALLS.load(Ordering::Relaxed)
}

/// The shapes the fleet ships: paper-baseline obs (84x84-ish stack →
/// 400 here), R2D2 hidden state, and the submission row counts the
/// `envs_per_actor` axis produces.
const OBS_LEN: usize = 400;
const HIDDEN: usize = 128;
const NUM_ACTIONS: usize = 4;
const SEQ_LEN: usize = 20;

fn seq(tag: f32) -> Sequence {
    Sequence {
        obs: vec![tag; SEQ_LEN * OBS_LEN],
        actions: vec![1; SEQ_LEN],
        rewards: vec![tag; SEQ_LEN],
        discounts: vec![0.99; SEQ_LEN],
        h0: vec![0.0; HIDDEN],
        c0: vec![0.0; HIDDEN],
        actor_id: 0,
        valid_len: SEQ_LEN,
    }
}

/// The CI gate: after one warmup round settles every buffer's
/// capacity, `iters` full encode→decode round-trips of submit, reply,
/// and sequence frames must not enter the allocator once.
fn assert_codec_allocation_free(rows: usize, iters: usize) {
    let obs: Vec<f32> = (0..rows * OBS_LEN).map(|i| i as f32 * 0.5).collect();
    let h: Vec<f32> = (0..rows * HIDDEN).map(|i| -(i as f32)).collect();
    let c: Vec<f32> = (0..rows * HIDDEN).map(|i| 0.25 * i as f32).collect();
    let q: Vec<f32> = (0..rows * NUM_ACTIONS).map(|i| i as f32 * 0.1).collect();
    let s = seq(1.0);

    let mut buf = Vec::new();
    let (mut o2, mut h2, mut c2) = (Vec::new(), Vec::new(), Vec::new());
    let (mut q2, mut hh2, mut cc2) = (Vec::new(), Vec::new(), Vec::new());
    let mut s2 = Sequence::default();

    let mut round = |buf: &mut Vec<u8>,
                     o2: &mut Vec<f32>,
                     h2: &mut Vec<f32>,
                     c2: &mut Vec<f32>,
                     q2: &mut Vec<f32>,
                     hh2: &mut Vec<f32>,
                     cc2: &mut Vec<f32>,
                     s2: &mut Sequence| {
        encode_submit(buf, 42, rows, &obs, &h, &c);
        let fr = &buf[4..];
        let hd = parse_header(fr).unwrap();
        decode_submit(payload(fr), hd.rows as usize, OBS_LEN, HIDDEN, o2, h2, c2).unwrap();

        encode_reply_ok(buf, 42, 0, rows, &q, &h, &c);
        let fr = &buf[4..];
        let hd = parse_header(fr).unwrap();
        decode_reply_ok(payload(fr), hd.rows as usize, NUM_ACTIONS, HIDDEN, q2, hh2, cc2)
            .unwrap();

        encode_sequence(buf, &s);
        let fr = &buf[4..];
        parse_header(fr).unwrap();
        decode_sequence(payload(fr), OBS_LEN, HIDDEN, s2).unwrap();
    };

    // Warmup: capacities settle (encode buf grows to the largest frame,
    // decode vecs to their row counts).
    for _ in 0..4 {
        round(
            &mut buf, &mut o2, &mut h2, &mut c2, &mut q2, &mut hh2, &mut cc2, &mut s2,
        );
    }
    let a0 = alloc_calls();
    for _ in 0..iters {
        round(
            &mut buf, &mut o2, &mut h2, &mut c2, &mut q2, &mut hh2, &mut cc2, &mut s2,
        );
    }
    let allocs = alloc_calls() - a0;
    assert_eq!(
        allocs, 0,
        "frame codec allocated {allocs} times over {iters} steady-state \
         encode+decode round-trips x {rows} rows (hard requirement: 0)"
    );
    // The decoded data actually round-tripped — the gate is not
    // measuring a short-circuited path.
    assert_eq!(o2, obs);
    assert_eq!(q2, q);
    assert_eq!(s2, s);
}

/// The §15 gate: one simulated quiet connection ticking at 1ms for
/// `iters` ticks — heartbeats firing, pongs answered, the liveness
/// window refreshed, the deadline estimator folding RTT samples. After
/// the ping buffer's capacity settles the whole control plane must not
/// enter the allocator once: these state machines run on every live
/// connection forever, so any per-tick allocation is a leak-shaped tax.
fn assert_liveness_allocation_free(iters: usize) {
    let t0 = Instant::now();
    let mut hb = Heartbeat::new(Duration::from_millis(5), t0);
    let mut lv = Liveness::new(Duration::from_millis(20), t0);
    let mut dl = DeadlineEwma::new(Duration::from_millis(20), 4.0);
    let mut buf = Vec::new();
    encode_ping(&mut buf, 0); // warmup: the 24-byte capacity settles
    let mut now = t0;
    let mut pings = 0u64;
    let a0 = alloc_calls();
    for i in 0..iters {
        now += Duration::from_millis(1);
        if hb.due(now) {
            encode_ping(&mut buf, i as u64);
            let hd = parse_header(&buf[4..]).unwrap();
            assert_eq!(hd.kind, FrameKind::Ping);
            encode_pong(&mut buf, hd.ticket);
            let hd = parse_header(&buf[4..]).unwrap();
            assert_eq!(hd.kind, FrameKind::Pong);
            hb.sent(now);
            lv.touch(now);
            pings += 1;
        }
        dl.observe(Duration::from_micros(500 + (i as u64 % 7) * 100));
        assert!(dl.deadline() >= Duration::from_millis(20));
        assert!(!lv.stale(now), "a heartbeating connection never goes stale");
    }
    let allocs = alloc_calls() - a0;
    assert_eq!(
        allocs, 0,
        "heartbeat/liveness/deadline control plane allocated {allocs} times \
         over {iters} ticks ({pings} ping/pong round-trips; hard requirement: 0)"
    );
    assert!(pings > 0, "the heartbeat never fired — the gate measured nothing");
}

/// The §16 gate: the serving-gate hot path — the admission switch,
/// global in-flight accounting, overload-ladder decisions across all
/// three priority classes, and breaker feeding — ticking once per
/// millisecond of synthetic clock. The gate is consulted on *every*
/// submission of every connection, so after construction it must never
/// enter the allocator: shed reasons are `&'static`, the policy is
/// Copy-struct arithmetic, and the breaker is a clock-free state
/// machine. Backend failures come in bursts of 8 so the breaker walks
/// closed → open → half-open → closed (and the fail-fast path runs).
fn assert_admission_allocation_free(iters: usize) {
    use rlarch::serve::{
        AdmissionDecision, AdmissionPolicy, CircuitBreaker, PriorityClass,
        ServeGate,
    };
    let t0 = Instant::now();
    let gate = ServeGate::new(
        Some(AdmissionPolicy::new(
            Duration::from_millis(8),
            64,
            256,
            Duration::from_millis(4),
            t0,
        )),
        Some(CircuitBreaker::new(3, Duration::from_millis(5), t0)),
    );
    let classes =
        [PriorityClass::Actor, PriorityClass::Eval, PriorityClass::Bulk];
    let mut now = t0;
    let mut admitted = 0u64;
    let a0 = alloc_calls();
    // The mixed loop: every hot-path operation in serve_infer's order,
    // classes rotating, backend failures in bursts of 8 ticks.
    for i in 0..iters {
        now += Duration::from_millis(1);
        let class = classes[i % 3];
        let queued = gate.begin_rows(8);
        if gate.breaker_allow(now)
            && gate.is_admitting()
            && gate.decide(class, 8, queued, now) == AdmissionDecision::Admit
        {
            admitted += 1;
            if (i / 8) % 2 == 0 {
                gate.breaker_on_failure(now);
            } else {
                gate.breaker_on_success();
            }
        }
        gate.end_rows(8);
        if i % 97 == 0 {
            // The reload drain switch flips on the hot path too.
            gate.set_admitting(false);
            gate.set_admitting(true);
        }
    }
    // Deterministic coda, still under the gate: saturate the overload
    // window with bulk rows until the ladder sheds, then walk the
    // breaker through open → fail-fast → half-open probe → closed.
    now += Duration::from_millis(1);
    let mut bulk_shed = 0u64;
    for _ in 0..20 {
        let queued = gate.begin_rows(8);
        if gate.decide(PriorityClass::Bulk, 8, queued, now)
            != AdmissionDecision::Admit
        {
            bulk_shed += 1;
        }
        gate.end_rows(8);
    }
    for _ in 0..3 {
        gate.breaker_on_failure(now);
    }
    let open_rejects = !gate.breaker_allow(now);
    now += Duration::from_millis(6); // past the cooloff
    let half_open_probe = gate.breaker_allow(now);
    gate.breaker_on_success();
    let closed_again = gate.breaker_allow(now);

    let allocs = alloc_calls() - a0;
    assert_eq!(
        allocs, 0,
        "serving gate allocated {allocs} times over {iters} admission \
         decisions (hard requirement: 0)"
    );
    assert_eq!(gate.inflight_rows(), 0, "begin/end row accounting balanced");
    assert!(admitted > 0, "the mixed loop admitted nothing");
    assert!(bulk_shed > 0, "the overload ladder never shed bulk traffic");
    assert!(
        open_rejects && half_open_probe && closed_again,
        "breaker cycle broke (open {open_rejects}, probe {half_open_probe}, \
         closed {closed_again})"
    );
}

fn main() {
    let quick = std::env::args().any(|a| a == "--quick");
    println!(
        "# micro_transport — fleet wire codec (obs {OBS_LEN}, H={HIDDEN}, T={SEQ_LEN})\n"
    );
    let (warm, iters) = if quick { (10, 200) } else { (100, 5_000) };
    let mut results: Vec<BenchResult> = Vec::new();
    let mut bytes_per: Vec<(String, usize)> = Vec::new();

    for &rows in &[1usize, 8, 32] {
        let obs: Vec<f32> = (0..rows * OBS_LEN).map(|i| i as f32 * 0.5).collect();
        let h = vec![0.5f32; rows * HIDDEN];
        let c = vec![-0.5f32; rows * HIDDEN];
        let q = vec![0.1f32; rows * NUM_ACTIONS];

        let mut buf = Vec::new();
        encode_submit(&mut buf, 1, rows, &obs, &h, &c);
        bytes_per.push((format!("submit_r{rows}"), buf.len()));
        results.push(bench(&format!("frame.encode_submit_r{rows}"), warm, iters, || {
            encode_submit(&mut buf, 1, rows, &obs, &h, &c);
        }));

        let mut sub = Vec::new();
        encode_submit(&mut sub, 1, rows, &obs, &h, &c);
        let (mut o2, mut h2, mut c2) = (Vec::new(), Vec::new(), Vec::new());
        results.push(bench(&format!("frame.decode_submit_r{rows}"), warm, iters, || {
            let fr = &sub[4..];
            decode_submit(payload(fr), rows, OBS_LEN, HIDDEN, &mut o2, &mut h2, &mut c2)
                .unwrap();
        }));

        let mut rep = Vec::new();
        encode_reply_ok(&mut rep, 1, 0, rows, &q, &h, &c);
        bytes_per.push((format!("reply_r{rows}"), rep.len()));
        let mut buf2 = Vec::new();
        results.push(bench(&format!("frame.encode_reply_r{rows}"), warm, iters, || {
            encode_reply_ok(&mut buf2, 1, 0, rows, &q, &h, &c);
        }));
        let (mut q2, mut hh2, mut cc2) = (Vec::new(), Vec::new(), Vec::new());
        results.push(bench(&format!("frame.decode_reply_r{rows}"), warm, iters, || {
            let fr = &rep[4..];
            decode_reply_ok(payload(fr), rows, NUM_ACTIONS, HIDDEN, &mut q2, &mut hh2, &mut cc2)
                .unwrap();
        }));
    }

    // Sequence frames (worker → central replay, once per T env steps).
    let s = seq(1.0);
    let mut buf = Vec::new();
    encode_sequence(&mut buf, &s);
    bytes_per.push(("sequence".into(), buf.len()));
    results.push(bench("frame.encode_sequence", warm, iters, || {
        encode_sequence(&mut buf, &s);
    }));
    let mut enc = Vec::new();
    encode_sequence(&mut enc, &s);
    let mut s2 = Sequence::default();
    results.push(bench("frame.decode_sequence", warm, iters, || {
        let fr = &enc[4..];
        decode_sequence(payload(fr), OBS_LEN, HIDDEN, &mut s2).unwrap();
    }));

    // Control-plane ping/pong (DESIGN.md §15): header-only 24-byte
    // frames, one per quiet heartbeat interval per connection.
    let mut pbuf = Vec::new();
    encode_ping(&mut pbuf, 1);
    bytes_per.push(("ping".into(), pbuf.len()));
    results.push(bench("frame.encode_ping", warm, iters, || {
        encode_ping(&mut pbuf, 1);
    }));
    let mut ping = Vec::new();
    encode_ping(&mut ping, 7);
    results.push(bench("frame.parse_ping", warm, iters, || {
        let hd = parse_header(&ping[4..]).unwrap();
        assert_eq!((hd.kind, hd.ticket), (FrameKind::Ping, 7));
    }));

    println!("{}", BenchResult::markdown_header());
    for r in &results {
        println!("{}", r.to_markdown_row());
    }

    // Frame sizes + implied single-core codec bandwidth: frame bytes
    // over the matching encode mean. This is the number the simarch
    // `net_bandwidth_bps` term is calibrated against (a socket can't
    // beat its serializer).
    println!("\n# frame sizes and single-core encode bandwidth\n");
    let mut csv = String::from("name,mean_s,p95_s,frame_bytes,encode_gbps\n");
    for r in &results {
        let bytes = bytes_per
            .iter()
            .find(|(n, _)| r.name.ends_with(n.as_str()) || r.name.contains(&format!("_{n}")))
            .map(|(_, b)| *b)
            .unwrap_or(0);
        let gbps = if r.name.contains("encode") && bytes > 0 && r.mean_s > 0.0 {
            bytes as f64 * 8.0 / r.mean_s / 1e9
        } else {
            0.0
        };
        if r.name.contains("encode") && bytes > 0 {
            println!("{}: {bytes} B/frame, {gbps:.2} Gbit/s", r.name);
        }
        csv.push_str(&format!(
            "{},{},{},{bytes},{gbps}\n",
            r.name, r.mean_s, r.p95_s
        ));
    }
    let p = rlarch::report::write_csv("micro_transport", &csv);
    println!("\ncsv: {}", p.display());

    // The allocation gate runs in both modes — CI enforces the property
    // via `--quick` rather than just reporting it.
    let gate_iters = if quick { 500 } else { 10_000 };
    assert_codec_allocation_free(8, gate_iters);
    println!(
        "\nframe codec steady-state allocator entries over {gate_iters} \
         encode+decode round-trips x 8 rows: 0 (hard requirement)"
    );

    let live_iters = if quick { 2_000 } else { 50_000 };
    assert_liveness_allocation_free(live_iters);
    println!(
        "heartbeat/liveness/deadline control plane allocator entries over \
         {live_iters} 1ms ticks: 0 (hard requirement)"
    );

    let admit_iters = if quick { 2_000 } else { 50_000 };
    assert_admission_allocation_free(admit_iters);
    println!(
        "serving gate (admission + breaker) allocator entries over \
         {admit_iters} decisions: 0 (hard requirement)"
    );
}
