//! Figure 3: impact of the number of actors on runtime, GPU power
//! (left), and performance per GPU Watt (right).
//!
//! Paper reference points: 4→40 actors = 5.8x speedup; 40→256 = only 2x
//! more (knee at the 40 hardware threads); GPU power grows with actors
//! from a ~70 W idle-heavy floor; perf/W improves monotonically.
//! Both the analytic steady-state model and the tick-DES are reported.

use rlarch::report::figure::{ascii_bar, Table};
use rlarch::report::write_csv;
use rlarch::simarch::{
    default_system, des, synthetic_paper_train_trace, synthetic_paper_trace,
    TraceSet,
};
use std::path::Path;

fn main() {
    let (infer, train) = match TraceSet::load(Path::new("artifacts")) {
        Ok(ts) => (
            ts.find("infer_paper_scale").expect("infer trace").clone(),
            ts.find("train_paper_scale").expect("train trace").clone(),
        ),
        Err(_) => {
            eprintln!("(artifacts missing: using synthetic paper-scale traces)");
            (
                synthetic_paper_trace(1, 1, 64),
                synthetic_paper_train_trace(2, 80, 16),
            )
        }
    };
    let m = default_system(infer, train);
    let actors = [1usize, 2, 4, 8, 16, 32, 40, 64, 128, 256];
    let fixed_frames = 1_000_000u64;

    println!("# Fig. 3 — actor sweep (normalized runtime, GPU power, perf/W)\n");
    let base_runtime = m.runtime_for(fixed_frames, actors[0]);
    let mut t = Table::new(&[
        "actors",
        "norm runtime",
        "",
        "power W",
        "perf/W",
        "batch",
        "GPU util",
    ]);
    let mut csv = String::from("actors,norm_runtime,power_w,perf_per_watt,gpu_util\n");
    for &n in &actors {
        let p = m.steady_state(n);
        let rt = m.runtime_for(fixed_frames, n) / base_runtime;
        t.row(&[
            n.to_string(),
            format!("{rt:.3}"),
            ascii_bar(rt, 24),
            format!("{:.0}", p.power_w),
            format!("{:.1}", p.perf_per_watt),
            format!("{:.1}", p.batch_size),
            format!("{:.2}", p.gpu_util),
        ]);
        csv.push_str(&format!(
            "{n},{rt},{},{},{}\n",
            p.power_w, p.perf_per_watt, p.gpu_util
        ));
    }
    println!("{}", t.to_markdown());

    let r4 = m.steady_state(4).env_rate;
    let r40 = m.steady_state(40).env_rate;
    let r256 = m.steady_state(256).env_rate;
    println!(
        "4→40 actors: {:.2}x speedup (paper: 5.8x); 40→256: {:.2}x more \
         (paper: 2x). Knee at the CPU hardware-thread count.\n",
        r40 / r4,
        r256 / r40
    );

    // DES cross-check on three points.
    println!("## tick-DES cross-check");
    let mut dt = Table::new(&["actors", "DES steps/s", "analytic steps/s", "ratio"]);
    for n in [8usize, 40, 128] {
        let d = des::simulate(&m, n, 0.3, 20e-6);
        let a = m.steady_state(n);
        dt.row(&[
            n.to_string(),
            format!("{:.0}", d.env_rate),
            format!("{:.0}", a.env_rate),
            format!("{:.2}", d.env_rate / a.env_rate),
        ]);
    }
    println!("{}", dt.to_markdown());

    let p = write_csv("fig3_actors", &csv);
    println!("csv: {}", p.display());
}
