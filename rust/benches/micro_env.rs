//! Micro-bench: environment suite step rates (the CPU-side workload the
//! paper's actor sweep is made of), per game, with and without the
//! frame-stack wrapper, plus the step-cost calibration knob — and the
//! batch-native SoA engine (DESIGN.md §13): a per-slot-vs-`step_all`
//! E-sweep whose speedup calibrates `SystemModel::env_dispatch_s`, plus
//! a counting-global-allocator gate hard-asserting that the SoA
//! engine's steady-state `step_all` never enters the allocator (the
//! property that lets one call step E slots with no per-slot dispatch
//! or allocation overhead).
//!
//! The tables here regenerate EXPERIMENTS.md §Perf (env step path).
//!
//! `--quick` shrinks every loop (the CI smoke run); the allocation gate
//! is asserted in both modes.

use rlarch::config::EnvConfig;
use rlarch::env::wrappers::Wrapped;
use rlarch::env::{make_batch_env, make_env, new_frame, registered_envs};
use rlarch::report::figure::Table;
use rlarch::report::write_csv;
use rlarch::util::prng::Pcg32;
use std::alloc::{GlobalAlloc, Layout, System};
use std::sync::atomic::{AtomicU64, Ordering};
use std::time::Instant;

/// Counts every allocator entry (alloc + realloc); frees are not
/// interesting here. Same gate pattern as `micro_trajectory`: the
/// counter makes "zero-allocation" checkable instead of inferred.
struct CountingAlloc;

static ALLOC_CALLS: AtomicU64 = AtomicU64::new(0);

unsafe impl GlobalAlloc for CountingAlloc {
    unsafe fn alloc(&self, layout: Layout) -> *mut u8 {
        ALLOC_CALLS.fetch_add(1, Ordering::Relaxed);
        System.alloc(layout)
    }

    unsafe fn dealloc(&self, ptr: *mut u8, layout: Layout) {
        System.dealloc(ptr, layout)
    }

    unsafe fn realloc(&self, ptr: *mut u8, layout: Layout, new_size: usize) -> *mut u8 {
        ALLOC_CALLS.fetch_add(1, Ordering::Relaxed);
        System.realloc(ptr, layout, new_size)
    }
}

#[global_allocator]
static GLOBAL: CountingAlloc = CountingAlloc;

fn alloc_calls() -> u64 {
    ALLOC_CALLS.load(Ordering::Relaxed)
}

fn env_cfg(name: &str) -> EnvConfig {
    EnvConfig {
        name: name.to_string(),
        ..Default::default()
    }
}

/// Step E per-slot `Wrapped` instances for `rounds` rounds; rows/s.
fn per_slot_rate(name: &str, e: usize, rounds: usize) -> f64 {
    let cfg = env_cfg(name);
    let mut slots: Vec<Wrapped> = (0..e)
        .map(|i| Wrapped::from_config(&cfg, i as u64).unwrap())
        .collect();
    let obs_len = slots[0].obs_len();
    let mut obs = vec![0.0f32; e * obs_len];
    for (i, w) in slots.iter_mut().enumerate() {
        w.reset(&mut obs[i * obs_len..(i + 1) * obs_len]);
    }
    let mut rng = Pcg32::seeded(3);
    let t0 = Instant::now();
    for _ in 0..rounds {
        for (i, w) in slots.iter_mut().enumerate() {
            w.step(rng.index(4), &mut obs[i * obs_len..(i + 1) * obs_len]);
        }
    }
    (rounds * e) as f64 / t0.elapsed().as_secs_f64()
}

/// Step the same E slots through one batch-native `step_all`; rows/s.
fn soa_rate(name: &str, e: usize, rounds: usize) -> f64 {
    let cfg = env_cfg(name);
    let mut benv = make_batch_env(&cfg, e, 0).unwrap();
    let mut obs = vec![0.0f32; e * benv.obs_len()];
    benv.reset_all(&mut obs);
    let mut actions = vec![0usize; e];
    let mut steps = Vec::with_capacity(e);
    let mut rng = Pcg32::seeded(3);
    let t0 = Instant::now();
    for _ in 0..rounds {
        for a in actions.iter_mut() {
            *a = rng.index(4);
        }
        steps.clear();
        benv.step_all(&actions, &mut obs, &mut steps);
    }
    (rounds * e) as f64 / t0.elapsed().as_secs_f64()
}

/// The CI gate: a warmed-up SoA engine must step all E slots without a
/// single allocator entry — across every registered env (NavMaze's
/// in-episode resets regenerate mazes on fixed scratch, so even its
/// auto-reset path must stay clean).
fn assert_step_all_allocation_free(e: usize, rounds: usize) {
    for name in registered_envs() {
        let cfg = env_cfg(name);
        let mut benv = make_batch_env(&cfg, e, 0).unwrap();
        let mut obs = vec![0.0f32; e * benv.obs_len()];
        benv.reset_all(&mut obs);
        let mut actions = vec![0usize; e];
        let mut steps = Vec::with_capacity(e);
        let mut rng = Pcg32::seeded(5);
        // Warmup: several episodes' worth, so auto-resets happen both
        // inside and after the measured window.
        for _ in 0..64 {
            for a in actions.iter_mut() {
                *a = rng.index(4);
            }
            steps.clear();
            benv.step_all(&actions, &mut obs, &mut steps);
        }
        let a0 = alloc_calls();
        for _ in 0..rounds {
            for a in actions.iter_mut() {
                *a = rng.index(4);
            }
            steps.clear();
            benv.step_all(&actions, &mut obs, &mut steps);
        }
        let allocs = alloc_calls() - a0;
        assert_eq!(
            allocs, 0,
            "{name}: SoA step_all allocated {allocs} times over {rounds} \
             rounds x {e} slots (hard requirement: 0 in steady state)"
        );
    }
}

fn main() {
    let quick = std::env::args().any(|a| a == "--quick");
    println!("# micro_env — environment step rates\n");
    let steps = if quick { 20_000 } else { 200_000 };
    let mut t = Table::new(&["env", "raw steps/s", "wrapped steps/s (stack=4)"]);
    let mut csv = String::from("env,raw_rate,wrapped_rate\n");
    for name in registered_envs() {
        // Raw env.
        let mut env = make_env(name, 1).unwrap();
        let mut frame = new_frame();
        let mut rng = Pcg32::seeded(2);
        env.reset(&mut frame);
        let t0 = Instant::now();
        for _ in 0..steps {
            if env.step(rng.index(4), &mut frame).done {
                env.reset(&mut frame);
            }
        }
        let raw = steps as f64 / t0.elapsed().as_secs_f64();

        // Wrapped (sticky + stack + episode bookkeeping).
        let cfg = env_cfg(name);
        let mut w = Wrapped::from_config(&cfg, 0).unwrap();
        let mut obs = vec![0.0f32; w.obs_len()];
        w.reset(&mut obs);
        let t0 = Instant::now();
        for _ in 0..steps {
            w.step(rng.index(4), &mut obs);
        }
        let wrapped = steps as f64 / t0.elapsed().as_secs_f64();

        t.row(&[
            name.to_string(),
            format!("{raw:.0}"),
            format!("{wrapped:.0}"),
        ]);
        csv.push_str(&format!("{name},{raw},{wrapped}\n"));
    }
    println!("{}", t.to_markdown());

    // Per-slot vs batch-native SoA engine across the vecenv E range:
    // identical work per row (same games, same wrappers' semantics), so
    // the ratio isolates per-slot dispatch + scattered-state overhead.
    // The per-row gap at large E divided into a per-call budget is the
    // measurement that feeds `SystemModel::env_dispatch_s`.
    let e_list: &[usize] = if quick { &[1, 8] } else { &[1, 4, 16, 64] };
    let mut st = Table::new(&[
        "env",
        "E",
        "per-slot rows/s",
        "soa rows/s",
        "soa/per-slot",
    ]);
    let mut soa_csv = String::from("env,e,per_slot_rate,soa_rate,speedup\n");
    for name in registered_envs() {
        for &e in e_list {
            let rounds = (steps / e).max(200);
            let ps = per_slot_rate(name, e, rounds);
            let soa = soa_rate(name, e, rounds);
            st.row(&[
                name.to_string(),
                e.to_string(),
                format!("{ps:.0}"),
                format!("{soa:.0}"),
                format!("{:.2}", soa / ps),
            ]);
            soa_csv.push_str(&format!("{name},{e},{ps},{soa},{}\n", soa / ps));
        }
    }
    println!("{}", st.to_markdown());

    // The allocation gate runs in both modes — CI enforces the property
    // via `--quick` rather than just reporting it.
    let gate_rounds = if quick { 2_000 } else { 20_000 };
    assert_step_all_allocation_free(16, gate_rounds);
    println!(
        "soa step_all steady-state allocator entries over {gate_rounds} \
         rounds x 16 slots, all envs: 0 (hard requirement)\n"
    );

    // Step-cost calibration: the knob that emulates ALE-weight envs.
    let mut ct = Table::new(&["step_cost_us", "measured steps/s", "target steps/s"]);
    for cost in [0u64, 50, 125, 500] {
        let cfg = EnvConfig {
            name: "catch".into(),
            step_cost_us: cost,
            ..Default::default()
        };
        let mut w = Wrapped::from_config(&cfg, 0).unwrap();
        let mut obs = vec![0.0f32; w.obs_len()];
        w.reset(&mut obs);
        let n = if cost == 0 {
            if quick {
                10_000
            } else {
                100_000
            }
        } else if quick {
            500
        } else {
            2_000
        };
        let t0 = Instant::now();
        for i in 0..n {
            w.step(i % 3, &mut obs);
        }
        let rate = n as f64 / t0.elapsed().as_secs_f64();
        let target = if cost == 0 {
            f64::NAN
        } else {
            1e6 / cost as f64
        };
        ct.row(&[
            cost.to_string(),
            format!("{rate:.0}"),
            if target.is_nan() {
                "—".into()
            } else {
                format!("{target:.0}")
            },
        ]);
    }
    println!("{}", ct.to_markdown());
    let p = write_csv("micro_env", &csv);
    println!("csv: {}", p.display());
    let p = write_csv("micro_env_soa", &soa_csv);
    println!("csv: {}", p.display());
}
