//! Micro-bench: environment suite step rates (the CPU-side workload the
//! paper's actor sweep is made of), per game, with and without the
//! frame-stack wrapper, plus the step-cost calibration knob.

use rlarch::config::EnvConfig;
use rlarch::env::wrappers::Wrapped;
use rlarch::env::{make_env, new_frame, registered_envs};
use rlarch::report::figure::Table;
use rlarch::report::write_csv;
use rlarch::util::prng::Pcg32;
use std::time::Instant;

fn main() {
    println!("# micro_env — environment step rates\n");
    let steps = 200_000;
    let mut t = Table::new(&["env", "raw steps/s", "wrapped steps/s (stack=4)"]);
    let mut csv = String::from("env,raw_rate,wrapped_rate\n");
    for name in registered_envs() {
        // Raw env.
        let mut env = make_env(name, 1).unwrap();
        let mut frame = new_frame();
        let mut rng = Pcg32::seeded(2);
        env.reset(&mut frame);
        let t0 = Instant::now();
        for _ in 0..steps {
            if env.step(rng.index(4), &mut frame).done {
                env.reset(&mut frame);
            }
        }
        let raw = steps as f64 / t0.elapsed().as_secs_f64();

        // Wrapped (sticky + stack + episode bookkeeping).
        let cfg = EnvConfig {
            name: name.to_string(),
            ..Default::default()
        };
        let mut w = Wrapped::from_config(&cfg, 0).unwrap();
        let mut obs = vec![0.0f32; w.obs_len()];
        w.reset(&mut obs);
        let t0 = Instant::now();
        for _ in 0..steps {
            w.step(rng.index(4), &mut obs);
        }
        let wrapped = steps as f64 / t0.elapsed().as_secs_f64();

        t.row(&[
            name.to_string(),
            format!("{raw:.0}"),
            format!("{wrapped:.0}"),
        ]);
        csv.push_str(&format!("{name},{raw},{wrapped}\n"));
    }
    println!("{}", t.to_markdown());

    // Step-cost calibration: the knob that emulates ALE-weight envs.
    let mut ct = Table::new(&["step_cost_us", "measured steps/s", "target steps/s"]);
    for cost in [0u64, 50, 125, 500] {
        let cfg = EnvConfig {
            name: "catch".into(),
            step_cost_us: cost,
            ..Default::default()
        };
        let mut w = Wrapped::from_config(&cfg, 0).unwrap();
        let mut obs = vec![0.0f32; w.obs_len()];
        w.reset(&mut obs);
        let n = if cost == 0 { 100_000 } else { 2_000 };
        let t0 = Instant::now();
        for i in 0..n {
            w.step(i % 3, &mut obs);
        }
        let rate = n as f64 / t0.elapsed().as_secs_f64();
        let target = if cost == 0 {
            f64::NAN
        } else {
            1e6 / cost as f64
        };
        ct.row(&[
            cost.to_string(),
            format!("{rate:.0}"),
            if target.is_nan() {
                "—".into()
            } else {
                format!("{target:.0}")
            },
        ]);
    }
    println!("{}", ct.to_markdown());
    let p = write_csv("micro_env", &csv);
    println!("csv: {}", p.display());
}
