//! Micro-bench: telemetry hot-path costs.
//!
//! Measures the instrumentation primitives the coordinator threads hit
//! on every iteration — `Timer::record` (striped per-thread accumulators
//! merged at snapshot) against the pre-stripe single-`Mutex<Summary>`
//! baseline, and span emission through a `SpanRecorder` (enabled ring
//! push and the disabled inert path) — under a counting global allocator
//! so the result is *allocations per operation*, not just wall time.
//! The acceptance bar (ISSUE 6) is zero steady-state allocations for
//! striped-timer record and span emission; the bench hard-asserts it,
//! so the CI `--quick` smoke run enforces the property rather than just
//! reporting it.
//!
//! The tables here regenerate EXPERIMENTS.md §Perf (telemetry path).
//!
//! `--quick` shrinks every loop (the CI smoke run).

use rlarch::metrics::Registry;
use rlarch::report::figure::Table;
use rlarch::telemetry::{SpanKind, SpanRecorder, Tracer};
use rlarch::util::stats::Summary;
use std::alloc::{GlobalAlloc, Layout, System};
use std::sync::atomic::{AtomicU64, Ordering};
use std::sync::{Barrier, Mutex};
use std::time::Instant;

/// Counts every allocator entry (alloc + realloc); frees are not
/// interesting here. The counter is what makes "zero-allocation"
/// checkable instead of inferred from timings.
struct CountingAlloc;

static ALLOC_CALLS: AtomicU64 = AtomicU64::new(0);

unsafe impl GlobalAlloc for CountingAlloc {
    unsafe fn alloc(&self, layout: Layout) -> *mut u8 {
        ALLOC_CALLS.fetch_add(1, Ordering::Relaxed);
        System.alloc(layout)
    }

    unsafe fn dealloc(&self, ptr: *mut u8, layout: Layout) {
        System.dealloc(ptr, layout)
    }

    unsafe fn realloc(&self, ptr: *mut u8, layout: Layout, new_size: usize) -> *mut u8 {
        ALLOC_CALLS.fetch_add(1, Ordering::Relaxed);
        System.realloc(ptr, layout, new_size)
    }
}

#[global_allocator]
static GLOBAL: CountingAlloc = CountingAlloc;

fn alloc_calls() -> u64 {
    ALLOC_CALLS.load(Ordering::Relaxed)
}

/// Worker threads hammering the same primitive — the batcher + actors +
/// learner population of a typical run.
const THREADS: usize = 8;

struct BenchResult {
    name: String,
    ops: u64,
    allocs: u64,
    elapsed_s: f64,
}

impl BenchResult {
    fn allocs_per_op(&self) -> f64 {
        self.allocs as f64 / self.ops as f64
    }

    fn ns_per_op(&self) -> f64 {
        self.elapsed_s * 1e9 / self.ops as f64
    }
}

/// Run `op` from `THREADS` threads, `ops_per_thread` times each, with a
/// warmup pass per thread before the measured window. `local` builds
/// per-thread state (stripe assignment, span recorder) during setup, so
/// the measured window contains only the steady-state operation. The
/// allocation/time window is bracketed by barriers: it opens after every
/// thread has warmed up and closes before any thread exits, so thread
/// spawn/teardown costs never leak into the measurement.
fn contended<L, S, F>(name: &str, ops_per_thread: u64, local: S, op: F) -> BenchResult
where
    S: Fn() -> L + Sync,
    F: Fn(&L) + Sync,
{
    let start = Barrier::new(THREADS + 1);
    let done = Barrier::new(THREADS + 1);
    let exit_gate = Barrier::new(THREADS + 1);
    let mut allocs = 0;
    let mut elapsed_s = 0.0;
    std::thread::scope(|s| {
        for _ in 0..THREADS {
            s.spawn(|| {
                let l = local();
                for _ in 0..1_000 {
                    op(&l);
                }
                start.wait();
                for _ in 0..ops_per_thread {
                    op(std::hint::black_box(&l));
                }
                done.wait();
                exit_gate.wait();
            });
        }
        start.wait();
        let a0 = alloc_calls();
        let t0 = Instant::now();
        done.wait();
        elapsed_s = t0.elapsed().as_secs_f64();
        allocs = alloc_calls() - a0;
        exit_gate.wait();
    });
    BenchResult {
        name: name.to_string(),
        ops: ops_per_thread * THREADS,
        allocs,
        elapsed_s,
    }
}

fn main() {
    let quick = std::env::args().any(|a| a == "--quick");
    let ops = if quick { 20_000 } else { 200_000 };
    println!("# micro_metrics — telemetry hot path ({THREADS} threads)\n");

    // Pre-stripe baseline: every thread serializes on one summary lock.
    let baseline_lock = Mutex::new(Summary::new());
    let baseline = contended(
        "timer: single Mutex<Summary> (baseline)",
        ops,
        || (),
        |()| {
            baseline_lock.lock().unwrap().add(1e-6);
        },
    );

    // The shipped striped timer: thread-local stripe, merged at snapshot.
    let registry = Registry::new();
    let timer = registry.timer("bench.striped");
    let striped = contended(
        "timer: striped record",
        ops,
        || timer.clone(),
        |t| t.record(1e-6),
    );
    assert_eq!(
        striped.allocs, 0,
        "striped Timer::record must be allocation-free in steady state"
    );
    let snap = timer.snapshot();
    assert_eq!(
        snap.count(),
        striped.ops + (THREADS as u64) * 1_000,
        "snapshot merge lost recordings"
    );

    // Span emission into per-thread rings (wrapping; drops are counted,
    // never allocated), plus the disabled inert path every run pays when
    // telemetry is off.
    let tracer = Tracer::new(4_096);
    let enabled = contended(
        "span: enabled ring emission",
        ops,
        || tracer.recorder("bench"),
        |r| {
            let _sp = r.span(SpanKind::EnvStep);
        },
    );
    assert_eq!(
        enabled.allocs, 0,
        "span emission must be allocation-free in steady state"
    );
    let disabled = contended(
        "span: disabled recorder",
        ops,
        SpanRecorder::disabled,
        |r| {
            let _sp = r.span(SpanKind::EnvStep);
        },
    );
    assert_eq!(
        disabled.allocs, 0,
        "the disabled span path must be allocation-free"
    );

    let mut t = Table::new(&["path", "ops", "allocs/op", "ns/op"]);
    let mut csv = String::from("path,ops,allocs_per_op,ns_per_op\n");
    for r in [&baseline, &striped, &enabled, &disabled] {
        t.row(&[
            r.name.clone(),
            r.ops.to_string(),
            format!("{:.4}", r.allocs_per_op()),
            format!("{:.1}", r.ns_per_op()),
        ]);
        csv.push_str(&format!(
            "{},{},{},{}\n",
            r.name,
            r.ops,
            r.allocs_per_op(),
            r.ns_per_op()
        ));
    }
    println!("{}", t.to_markdown());
    println!(
        "striped vs mutex under {THREADS}-thread contention: {:.2}x\n",
        baseline.ns_per_op() / striped.ns_per_op().max(1e-9)
    );
    let p = rlarch::report::write_csv("micro_metrics", &csv);
    println!("csv: {}", p.display());
}
