//! Figure 4: performance slowdown when reducing the number of GPU SMs —
//! the paper's CPU/GPU-ratio experiment (emulating a larger ratio by
//! disabling SMs, since adding CPU threads to a fixed box is hard).
//!
//! Paper reference: 80→40 SMs (ratio 1/2 → 1) costs only ~6%; pushing to
//! very few SMs makes the GPU the system bottleneck. Conclusion 3:
//! provision CPU threads >= GPU SMs (ratio >= 1).

use rlarch::report::figure::{ascii_bar, Table};
use rlarch::report::write_csv;
use rlarch::simarch::{
    default_system, synthetic_paper_train_trace, synthetic_paper_trace, TraceSet,
};
use std::path::Path;

fn main() {
    let (infer, train) = match TraceSet::load(Path::new("artifacts")) {
        Ok(ts) => (
            ts.find("infer_paper_scale").expect("infer trace").clone(),
            ts.find("train_paper_scale").expect("train trace").clone(),
        ),
        Err(_) => {
            eprintln!("(artifacts missing: using synthetic paper-scale traces)");
            (
                synthetic_paper_trace(1, 1, 64),
                synthetic_paper_train_trace(2, 80, 16),
            )
        }
    };
    let m = default_system(infer, train);
    let n_actors = 40; // the paper's box: 40 hardware threads
    let sms = [80usize, 60, 40, 20, 10, 8, 4, 2];

    println!("# Fig. 4 — slowdown vs GPU SM count (40 CPU hardware threads)\n");
    let base = m.steady_state(n_actors).env_rate;
    let mut t = Table::new(&["SMs", "CPU/GPU ratio", "slowdown", "", "GPU util"]);
    let mut csv = String::from("sms,ratio,slowdown,gpu_util\n");
    for &s in &sms {
        let sys = m.with_sms(s);
        let p = sys.steady_state(n_actors);
        let slow = base / p.env_rate;
        t.row(&[
            s.to_string(),
            format!("{:.3}", 40.0 / s as f64),
            format!("{slow:.3}x"),
            ascii_bar((slow - 1.0) / 8.0, 24),
            format!("{:.2}", p.gpu_util),
        ]);
        csv.push_str(&format!("{s},{},{slow},{}\n", 40.0 / s as f64, p.gpu_util));
    }
    println!("{}", t.to_markdown());

    let s40 = base / m.with_sms(40).steady_state(n_actors).env_rate;
    println!(
        "80→40 SMs (CPU/GPU ratio 1/2 → 1): {:.1}% slowdown (paper: 6%) — \
         large GPU headroom at today's ratios.",
        (s40 - 1.0) * 100.0
    );
    println!(
        "named systems: DGX-1 ratio 1/16 (paper: needs 16x more CPU), \
         DGX-A100 1/4 (needs 4x); this experiment's baseline slice is 1/2.\n"
    );

    let p = write_csv("fig4_sm_sweep", &csv);
    println!("csv: {}", p.display());
}
