//! Micro-bench: prioritized sequence replay hot paths (add / sample /
//! update-priorities), the batched-ingest lock-amortization grid
//! (insert_batch × shards, counter-based), the shards × writer-threads
//! contention grid, and the prefetch on/off learner-cycle comparison —
//! the learner-side substrate (Reverb-equivalent). The tables here
//! regenerate EXPERIMENTS.md §Perf.
//!
//! Also carries the counting-global-allocator gate for the
//! generation-pinned sample path: a warmed-up
//! `SequenceReplay::sample_into` (reused scratch/slots/generations,
//! borrowed rows visited under the shard lock) must never enter the
//! allocator — the property that removes the learner's per-batch `Arc`
//! churn (DESIGN.md §8).
//!
//! `--quick` shrinks every loop (the CI smoke run); the allocation
//! gate is asserted in both modes.

use rlarch::config::LearnerConfig;
use rlarch::coordinator::learner::{run_learner, LearnerArgs};
use rlarch::exec::ShutdownToken;
use rlarch::metrics::Registry;
use rlarch::replay::{IngestQueue, ReplayConfig, SampleScratch, SequenceReplay};
use rlarch::report::figure::Table;
use rlarch::report::{bench, BenchResult};
use rlarch::rl::Sequence;
use rlarch::runtime::{Backend, MockModel, ModelDims};
use rlarch::util::prng::Pcg32;
use std::alloc::{GlobalAlloc, Layout, System};
use std::sync::atomic::{AtomicBool, AtomicU64, Ordering};
use std::sync::Arc;
use std::time::{Duration, Instant};

/// Counts every allocator entry (alloc + realloc); frees are not
/// interesting here. Same gate pattern as `micro_env` /
/// `micro_transport`.
struct CountingAlloc;

static ALLOC_CALLS: AtomicU64 = AtomicU64::new(0);

unsafe impl GlobalAlloc for CountingAlloc {
    unsafe fn alloc(&self, layout: Layout) -> *mut u8 {
        ALLOC_CALLS.fetch_add(1, Ordering::Relaxed);
        System.alloc(layout)
    }

    unsafe fn dealloc(&self, ptr: *mut u8, layout: Layout) {
        System.dealloc(ptr, layout)
    }

    unsafe fn realloc(&self, ptr: *mut u8, layout: Layout, new_size: usize) -> *mut u8 {
        ALLOC_CALLS.fetch_add(1, Ordering::Relaxed);
        System.realloc(ptr, layout, new_size)
    }
}

#[global_allocator]
static GLOBAL: CountingAlloc = CountingAlloc;

fn alloc_calls() -> u64 {
    ALLOC_CALLS.load(Ordering::Relaxed)
}

fn seq(obs_len: usize, t: usize, hidden: usize, tag: f32) -> Sequence {
    Sequence {
        obs: vec![tag; t * obs_len],
        actions: vec![0; t],
        rewards: vec![tag; t],
        discounts: vec![0.99; t],
        h0: vec![0.0; hidden],
        c0: vec![0.0; hidden],
        actor_id: 0,
        valid_len: t,
    }
}

/// One contention-grid cell: `writers` threads hammer `add` while one
/// sampler runs sample+update cycles. Returns (adds/s, sampler cycles,
/// contended lock acquisitions).
fn contention_cell(
    shards: usize,
    writers: usize,
    adds_per_writer: usize,
) -> (f64, u64, u64) {
    let r = Arc::new(SequenceReplay::new(ReplayConfig {
        capacity: 4_096,
        shards,
        ..Default::default()
    }));
    for i in 0..64 {
        r.add(seq(400, 20, 128, i as f32));
    }
    let stop = Arc::new(AtomicBool::new(false));
    let t0 = Instant::now();
    let cycles = std::thread::scope(|s| {
        let mut writer_joins = Vec::new();
        for w in 0..writers {
            let r = r.clone();
            writer_joins.push(s.spawn(move || {
                let template = seq(400, 20, 128, w as f32);
                for _ in 0..adds_per_writer {
                    r.add(template.clone());
                }
            }));
        }
        let sampler = s.spawn({
            let r = r.clone();
            let stop = stop.clone();
            move || {
                let mut rng = Pcg32::seeded(1);
                let prios = vec![0.5f32; 16];
                let mut cycles = 0u64;
                while !stop.load(Ordering::Relaxed) {
                    if let Some(b) = r.sample(16, &mut rng) {
                        r.update_priorities(&b.slots, &b.generations, &prios);
                        cycles += 1;
                    }
                }
                cycles
            }
        });
        for j in writer_joins {
            j.join().unwrap();
        }
        stop.store(true, Ordering::Relaxed);
        sampler.join().unwrap()
    });
    let elapsed = t0.elapsed().as_secs_f64().max(1e-9);
    let adds = (writers * adds_per_writer) as f64;
    (adds / elapsed, cycles, r.shard_contention())
}

/// One learner-cycle run: prefetch on/off over a pre-filled buffer with
/// injected mock train latency. Returns (learner steps/s, prefetch
/// occupancy).
fn learner_cycle(
    prefetch_depth: usize,
    steps: usize,
    train_latency: Duration,
) -> (f64, f64) {
    let dims = ModelDims {
        obs_len: 400,
        hidden: 128,
        num_actions: 4,
        seq_len: 20,
        train_batch: 16,
    };
    let replay = Arc::new(SequenceReplay::new(ReplayConfig {
        capacity: 1_024,
        ..Default::default()
    }));
    for i in 0..256 {
        replay.add(seq(dims.obs_len, dims.seq_len, dims.hidden, i as f32));
    }
    let backend = Backend::Mock(Arc::new(
        MockModel::new(dims, 7).with_train_latency(train_latency),
    ));
    let metrics = Registry::new();
    let cfg = LearnerConfig {
        train_batch: dims.train_batch,
        min_replay: 64,
        max_steps: steps,
        prefetch_depth,
        target_update_interval: 1_000_000,
        ..Default::default()
    };
    let t0 = Instant::now();
    let stats = run_learner(LearnerArgs {
        cfg,
        dims,
        backend,
        replay,
        metrics: metrics.clone(),
        shutdown: ShutdownToken::new(),
        loss_every: 0,
        seed: 9,
        on_batch: None,
    })
    .unwrap();
    let elapsed = t0.elapsed().as_secs_f64().max(1e-9);
    (
        stats.steps as f64 / elapsed,
        metrics.gauge("learner.prefetch_occupancy").get(),
    )
}

fn main() {
    let quick = std::env::args().any(|a| a == "--quick");
    println!("# micro_replay — R2D2 sequence replay (obs 400, T=20, H=128)\n");
    let cfg = || ReplayConfig {
        capacity: 4_096,
        alpha: 0.9,
        min_priority: 1e-3,
        shards: 1,
    };
    let (warm, iters) = if quick { (10, 100) } else { (100, 2_000) };
    let mut results: Vec<BenchResult> = Vec::new();

    // add (ring insert at max priority)
    let r = SequenceReplay::new(cfg());
    let template = seq(400, 20, 128, 1.0);
    results.push(bench("replay.add", warm, iters, || {
        r.add(template.clone());
    }));

    // sample batch of 16
    let r = SequenceReplay::new(cfg());
    for i in 0..4_096 {
        r.add(seq(400, 20, 128, i as f32));
    }
    let mut rng = Pcg32::seeded(1);
    let (warm_s, iters_s) = if quick { (5, 50) } else { (20, 500) };
    results.push(bench("replay.sample_b16", warm_s, iters_s, || {
        std::hint::black_box(r.sample(16, &mut rng).unwrap());
    }));

    // Generation-pinned sample path: same draws, borrowed rows, reused
    // scratch — the learner's steady-state path (no Arc clones).
    let mut scratch = SampleScratch::new();
    let (mut slots, mut gens) = (Vec::new(), Vec::new());
    let mut sink = 0.0f32;
    results.push(bench("replay.sample_into_b16", warm_s, iters_s, || {
        let ok = r.sample_into(16, &mut rng, &mut scratch, &mut slots, &mut gens, |_, s| {
            sink += s.obs[0];
        });
        assert!(ok);
    }));
    std::hint::black_box(sink);

    // update priorities for 16 slots
    let batch = r.sample(16, &mut rng).unwrap();
    let prios = vec![0.5f32; 16];
    let (warm_u, iters_u) = if quick { (10, 200) } else { (100, 5_000) };
    results.push(bench("replay.update_prio_16", warm_u, iters_u, || {
        r.update_priorities(&batch.slots, &batch.generations, &prios);
    }));

    // end-to-end learner-side cycle: sample + update
    results.push(bench("replay.cycle_b16", warm_s, iters_s, || {
        let b = r.sample(16, &mut rng).unwrap();
        r.update_priorities(&b.slots, &b.generations, &prios);
    }));

    // The allocation gate (both modes): a warmed-up sample_into with
    // reused scratch/slots/generations must never enter the allocator —
    // the ISSUE 8 satellite acceptance for the Arc-churn removal.
    let gate_iters = if quick { 500 } else { 10_000 };
    {
        let mut scratch = SampleScratch::new();
        let (mut slots, mut gens) = (Vec::new(), Vec::new());
        let mut sink = 0.0f32;
        for _ in 0..8 {
            r.sample_into(16, &mut rng, &mut scratch, &mut slots, &mut gens, |_, s| {
                sink += s.obs[0];
            });
        }
        let a0 = alloc_calls();
        for _ in 0..gate_iters {
            let ok = r.sample_into(16, &mut rng, &mut scratch, &mut slots, &mut gens, |_, s| {
                sink += s.obs[0];
            });
            assert!(ok);
        }
        let allocs = alloc_calls() - a0;
        assert_eq!(
            allocs, 0,
            "sample_into allocated {allocs} times over {gate_iters} \
             steady-state b16 draws (hard requirement: 0)"
        );
        std::hint::black_box(sink);
        println!(
            "\nsample_into steady-state allocator entries over {gate_iters} \
             b16 draws: 0 (hard requirement)\n"
        );
    }

    println!("{}", BenchResult::markdown_header());
    for r in &results {
        println!("{}", r.to_markdown_row());
    }
    let csv: String = std::iter::once("name,mean_s,p95_s".to_string())
        .chain(results.iter().map(|r| format!("{},{},{}", r.name, r.mean_s, r.p95_s)))
        .collect::<Vec<_>>()
        .join("\n");
    let p = rlarch::report::write_csv("micro_replay", &csv);
    println!("\ncsv: {}", p.display());

    // Batched-ingest grid: shard-lock acquisitions per sequence across
    // insert_batch settings (counter-based: SequenceReplay counts every
    // lock acquisition). One flush of k sequences over S shards costs
    // min(k, S) acquisitions instead of k — the ISSUE 4 acceptance
    // shape is the drop at insert_batch >= 4.
    println!("\n# batched ingest — shard-lock acquisitions per sequence\n");
    let ingest_n = if quick { 512 } else { 8_192 };
    let mut it = Table::new(&[
        "shards",
        "insert_batch",
        "locks/seq",
        "adds/s",
    ]);
    let mut it_csv = String::from("shards,insert_batch,locks_per_seq,adds_per_sec\n");
    for &shards in &[1usize, 4] {
        for &insert_batch in &[1usize, 4, 16] {
            let r = Arc::new(SequenceReplay::new(ReplayConfig {
                capacity: 4_096,
                shards,
                ..Default::default()
            }));
            let mut q = IngestQueue::new(r.clone(), insert_batch);
            let template = seq(400, 20, 128, 1.0);
            let locks0 = r.lock_acquisitions();
            let t0 = Instant::now();
            for _ in 0..ingest_n {
                q.push(template.clone());
            }
            q.flush();
            let elapsed = t0.elapsed().as_secs_f64().max(1e-9);
            let locks_per_seq =
                (r.lock_acquisitions() - locks0) as f64 / ingest_n as f64;
            it.row(&[
                shards.to_string(),
                insert_batch.to_string(),
                format!("{locks_per_seq:.3}"),
                format!("{:.0}", ingest_n as f64 / elapsed),
            ]);
            it_csv.push_str(&format!(
                "{shards},{insert_batch},{locks_per_seq},{}\n",
                ingest_n as f64 / elapsed
            ));
        }
    }
    println!("{}", it.to_markdown());
    let p = rlarch::report::write_csv("micro_replay_ingest", &it_csv);
    println!("csv: {}", p.display());

    // Shards × writer-threads contention grid: actor inserts stripe
    // across shard mutexes while the learner samples + updates.
    println!("\n# shard contention — writers hammer add vs one sampler\n");
    let adds_per_writer = if quick { 300 } else { 5_000 };
    let mut grid = Table::new(&[
        "shards",
        "writers",
        "adds/s",
        "sampler cycles",
        "contended locks",
    ]);
    let mut grid_csv =
        String::from("shards,writers,adds_per_sec,sampler_cycles,contended\n");
    for &shards in &[1usize, 2, 4, 8] {
        for &writers in &[1usize, 2, 4] {
            let (rate, cycles, contended) =
                contention_cell(shards, writers, adds_per_writer);
            grid.row(&[
                shards.to_string(),
                writers.to_string(),
                format!("{rate:.0}"),
                cycles.to_string(),
                contended.to_string(),
            ]);
            grid_csv
                .push_str(&format!("{shards},{writers},{rate},{cycles},{contended}\n"));
        }
    }
    println!("{}", grid.to_markdown());
    let p = rlarch::report::write_csv("micro_replay_contention", &grid_csv);
    println!("csv: {}", p.display());

    // Prefetch on/off learner-cycle comparison: injected train latency
    // gives the pipeline GPU time to hide the sample+assemble under.
    println!("\n# learner cycle — prefetch off vs on (injected train latency)\n");
    let steps = if quick { 10 } else { 40 };
    let latency = Duration::from_micros(if quick { 300 } else { 1_000 });
    let mut lt = Table::new(&["prefetch depth", "learner steps/s", "occupancy"]);
    let mut lt_csv = String::from("prefetch_depth,steps_per_sec,occupancy\n");
    for depth in [1usize, 2, 3] {
        let (rate, occ) = learner_cycle(depth, steps, latency);
        // The serialized loop has no prefetch stage: occupancy is
        // not-applicable there, not a measured 0%.
        lt.row(&[
            depth.to_string(),
            format!("{rate:.1}"),
            if depth == 1 {
                "n/a".to_string()
            } else {
                format!("{occ:.2}")
            },
        ]);
        lt_csv.push_str(&format!("{depth},{rate},{occ}\n"));
    }
    println!("{}", lt.to_markdown());
    let p = rlarch::report::write_csv("micro_replay_prefetch", &lt_csv);
    println!("csv: {}", p.display());
}
