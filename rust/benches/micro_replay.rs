//! Micro-bench: prioritized sequence replay hot paths (add / sample /
//! update-priorities) — the learner-side substrate (Reverb-equivalent).

use rlarch::replay::{ReplayConfig, SequenceReplay};
use rlarch::report::{bench, BenchResult};
use rlarch::rl::Sequence;
use rlarch::util::prng::Pcg32;

fn seq(obs_len: usize, t: usize, hidden: usize, tag: f32) -> Sequence {
    Sequence {
        obs: vec![tag; t * obs_len],
        actions: vec![0; t],
        rewards: vec![tag; t],
        discounts: vec![0.99; t],
        h0: vec![0.0; hidden],
        c0: vec![0.0; hidden],
        actor_id: 0,
        valid_len: t,
    }
}

fn main() {
    println!("# micro_replay — R2D2 sequence replay (obs 400, T=20, H=128)\n");
    let cfg = || ReplayConfig {
        capacity: 4_096,
        alpha: 0.9,
        min_priority: 1e-3,
    };
    let mut results: Vec<BenchResult> = Vec::new();

    // add (ring insert at max priority)
    let r = SequenceReplay::new(cfg());
    let template = seq(400, 20, 128, 1.0);
    results.push(bench("replay.add", 100, 2_000, || {
        r.add(template.clone());
    }));

    // sample batch of 16
    let r = SequenceReplay::new(cfg());
    for i in 0..4_096 {
        r.add(seq(400, 20, 128, i as f32));
    }
    let mut rng = Pcg32::seeded(1);
    results.push(bench("replay.sample_b16", 20, 500, || {
        std::hint::black_box(r.sample(16, &mut rng).unwrap());
    }));

    // update priorities for 16 slots
    let batch = r.sample(16, &mut rng).unwrap();
    let prios = vec![0.5f32; 16];
    results.push(bench("replay.update_prio_16", 100, 5_000, || {
        r.update_priorities(&batch.slots, &prios);
    }));

    // end-to-end learner-side cycle: sample + update
    results.push(bench("replay.cycle_b16", 20, 500, || {
        let b = r.sample(16, &mut rng).unwrap();
        r.update_priorities(&b.slots, &prios);
    }));

    println!("{}", BenchResult::markdown_header());
    for r in &results {
        println!("{}", r.to_markdown_row());
    }
    let csv: String = std::iter::once("name,mean_s,p95_s".to_string())
        .chain(results.iter().map(|r| format!("{},{},{}", r.name, r.mean_s, r.p95_s)))
        .collect::<Vec<_>>()
        .join("\n");
    let p = rlarch::report::write_csv("micro_replay", &csv);
    println!("\ncsv: {}", p.display());
}
