//! Micro-bench: the vectorized environment engine.
//!
//! Measures aggregate env-steps/sec of one `VecEnv` as the slot count E
//! grows (the engine's scaling curve on a single thread), and compares
//! a 1-slot `VecEnv` against the bare `Wrapped` single-env path to show
//! the engine adds no per-step overhead at E = 1.

use rlarch::config::EnvConfig;
use rlarch::env::wrappers::Wrapped;
use rlarch::report::figure::Table;
use rlarch::report::write_csv;
use rlarch::util::prng::Pcg32;
use rlarch::vecenv::VecEnv;
use std::time::Instant;

fn main() {
    println!("# micro_vecenv — vectorized environment engine step rates\n");
    let cfg = EnvConfig {
        name: "catch".into(),
        step_cost_us: 0,
        ..Default::default()
    };

    // Baseline: the single-env Wrapped path.
    let steps = 100_000usize;
    let mut w = Wrapped::from_config(&cfg, 1).unwrap();
    let mut obs = vec![0.0f32; w.obs_len()];
    let mut rng = Pcg32::seeded(3);
    w.reset(&mut obs);
    let t0 = Instant::now();
    for _ in 0..steps {
        w.step(rng.index(4), &mut obs);
    }
    let wrapped_rate = steps as f64 / t0.elapsed().as_secs_f64();
    println!("single `Wrapped` baseline: {wrapped_rate:.0} env-steps/s\n");

    // VecEnv over the envs_per_actor sweep: total env steps per second
    // of one engine (one thread) as slots scale.
    let mut t = Table::new(&[
        "envs_per_actor",
        "env steps/s",
        "vs E=1",
        "steps/s per env",
    ]);
    let mut csv = String::from("envs_per_actor,steps_per_sec,per_env\n");
    let mut base_rate = 0.0f64;
    for e in [1usize, 2, 4, 8, 16, 32, 64] {
        let mut venv = VecEnv::from_config(&cfg, e, 1).unwrap();
        let mut obs = venv.new_obs_batch();
        let mut actions = vec![0usize; e];
        let mut rng = Pcg32::seeded(7);
        venv.reset_all(&mut obs);
        let rounds = (200_000 / e).max(500);
        let t0 = Instant::now();
        for _ in 0..rounds {
            for a in actions.iter_mut() {
                *a = rng.index(4);
            }
            venv.step_all(&actions, &mut obs);
        }
        let rate = (rounds * e) as f64 / t0.elapsed().as_secs_f64();
        if e == 1 {
            base_rate = rate;
        }
        t.row(&[
            e.to_string(),
            format!("{rate:.0}"),
            format!("{:.2}x", rate / base_rate),
            format!("{:.0}", rate / e as f64),
        ]);
        csv.push_str(&format!("{e},{rate},{}\n", rate / e as f64));
    }
    println!("{}", t.to_markdown());
    println!(
        "E=1 engine vs bare Wrapped: {:.2}x (≈1.0 means the vecenv layer \
         is overhead-free at the seed topology)",
        base_rate / wrapped_rate
    );
    let p = write_csv("micro_vecenv", &csv);
    println!("csv: {}", p.display());
}
