//! Micro-bench: the split-phase policy layer's pipeline surface.
//!
//! Runs the real actor loop (vecenv + central batcher + mock backend
//! with injected inference latency) at pipeline depths 1/2/4 and
//! reports env-steps/sec plus the overlap time the actor banked while
//! inference was in flight — the policy-layer lever on the paper's
//! CPU/GPU ratio: depth 1 serializes env CPU work behind GPU latency,
//! deeper pipelines hide it.

use rlarch::config::SystemConfig;
use rlarch::coordinator::actor::{run_actor, ActorArgs};
use rlarch::coordinator::Batcher;
use rlarch::exec::ShutdownToken;
use rlarch::metrics::Registry;
use rlarch::policy::{CentralClient, PolicyClient};
use rlarch::replay::{ReplayConfig, SequenceReplay};
use rlarch::report::figure::Table;
use rlarch::report::write_csv;
use rlarch::runtime::{Backend, MockModel, ModelDims};
use std::sync::Arc;
use std::time::{Duration, Instant};

fn run_depth(depth: usize, envs: usize, rounds: u64, latency_us: u64) -> (f64, f64) {
    let mut cfg = SystemConfig::default();
    cfg.env.name = "catch".into();
    cfg.env.step_cost_us = 200; // ALE-class env weight
    cfg.actors.num_actors = 1;
    cfg.actors.envs_per_actor = envs;
    cfg.actors.pipeline_depth = depth;
    cfg.learner.burn_in = 2;
    cfg.learner.unroll_len = 4;
    cfg.learner.seq_overlap = 2;
    cfg.batcher.max_batch = envs;
    cfg.batcher.batch_sizes = vec![envs];
    cfg.batcher.timeout_us = 100;
    let dims = ModelDims {
        obs_len: 400,
        hidden: 16,
        num_actions: 4,
        seq_len: 6,
        train_batch: 2,
    };
    let backend = Backend::Mock(Arc::new(
        MockModel::new(dims, 9).with_infer_latency(Duration::from_micros(latency_us)),
    ));
    let metrics = Registry::new();
    let (batcher, handle) =
        Batcher::spawn(cfg.batcher.clone(), backend, metrics.clone());
    let policy: Box<dyn PolicyClient> =
        Box::new(CentralClient::new(handle.clone(), 0, dims, &metrics));
    let replay = Arc::new(SequenceReplay::new(ReplayConfig {
        capacity: 4_096,
        ..Default::default()
    }));
    let t0 = Instant::now();
    let stats = run_actor(ActorArgs {
        id: 0,
        cfg,
        dims,
        policy,
        replay,
        metrics: metrics.clone(),
        shutdown: ShutdownToken::new(),
        max_rounds: Some(rounds),
    })
    .unwrap();
    let elapsed = t0.elapsed().as_secs_f64();
    drop(handle);
    batcher.join();
    let overlap: f64 = {
        let s = metrics.timer("actor.overlap_seconds").snapshot();
        if s.count() > 0 {
            s.mean() * s.count() as f64
        } else {
            0.0
        }
    };
    (stats.env_steps as f64 / elapsed, overlap)
}

fn main() {
    println!("# micro_policy — actor pipeline depth sweep (mock backend)\n");
    let envs = 8;
    let rounds = 100;
    let mut t = Table::new(&[
        "pipeline depth",
        "envs/actor",
        "env steps/s",
        "vs depth 1",
        "overlap s",
    ]);
    let mut csv = String::from("depth,envs,steps_per_sec,overlap_seconds\n");
    let mut base = 0.0f64;
    for &(depth, latency_us) in &[(1usize, 1_000u64), (2, 1_000), (4, 1_000)] {
        let (rate, overlap) = run_depth(depth, envs, rounds, latency_us);
        if depth == 1 {
            base = rate;
        }
        t.row(&[
            depth.to_string(),
            envs.to_string(),
            format!("{rate:.0}"),
            format!("{:.2}x", rate / base.max(1e-9)),
            format!("{overlap:.3}"),
        ]);
        csv.push_str(&format!("{depth},{envs},{rate},{overlap}\n"));
    }
    println!("{}", t.to_markdown());
    println!(
        "pipelining wins: depth 1 serializes {envs} env steps behind every \
         inference round-trip; deeper pipelines step one slot group while \
         the others' rows are in flight, hiding the env CPU work the paper \
         says dominates."
    );
    let p = write_csv("micro_policy", &csv);
    println!("csv: {}", p.display());
}
