//! Micro-bench: PJRT runtime hot paths on the real artifacts — batched
//! inference latency per compiled batch size (the quantity the central
//! batcher amortizes) and the full R2D2 train step. Skips gracefully
//! when artifacts are absent.

use rlarch::report::figure::Table;
use rlarch::report::{bench, write_csv, BenchResult};
use rlarch::runtime::{InferRequest, TrainBatch, XlaRuntime};
use rlarch::util::prng::Pcg32;
use std::path::Path;

fn main() {
    let dir = Path::new("artifacts");
    if !dir.join("manifest.json").exists() {
        eprintln!("micro_runtime: run `make artifacts` first (skipping)");
        return;
    }
    println!("# micro_runtime — PJRT execution on the real artifacts\n");
    let mut rt = XlaRuntime::load(dir, None, true).unwrap();
    let d = rt.dims();

    // Inference latency per batch size + per-row cost.
    let mut t = Table::new(&["batch", "latency", "per-row", "rows/s"]);
    let mut csv = String::from("batch,latency_s,per_row_s\n");
    for b in rt.manifest.infer_batch_sizes() {
        let req = InferRequest {
            n: b,
            h: vec![0.1; b * d.hidden],
            c: vec![0.1; b * d.hidden],
            obs: vec![0.4; b * d.obs_len],
        };
        let r = bench(&format!("infer_b{b}"), 5, 40, || {
            std::hint::black_box(rt.infer(&req).unwrap());
        });
        t.row(&[
            b.to_string(),
            rlarch::report::bench::fmt_time(r.mean_s),
            rlarch::report::bench::fmt_time(r.mean_s / b as f64),
            format!("{:.0}", b as f64 / r.mean_s),
        ]);
        csv.push_str(&format!("{b},{},{}\n", r.mean_s, r.mean_s / b as f64));
    }
    println!("{}", t.to_markdown());
    println!(
        "batching amortization is the SEED central-inference premise: \
         per-row cost falls with batch size.\n"
    );

    // Train step.
    let bt = d.train_batch * d.seq_len;
    let mut rng = Pcg32::seeded(3);
    let batch = TrainBatch {
        batch: d.train_batch,
        obs: (0..bt * d.obs_len).map(|_| rng.next_f32()).collect(),
        actions: (0..bt).map(|_| rng.index(d.num_actions) as i32).collect(),
        rewards: (0..bt).map(|_| rng.next_f32() - 0.5).collect(),
        discounts: vec![0.997; bt],
        h0: vec![0.0; d.train_batch * d.hidden],
        c0: vec![0.0; d.train_batch * d.hidden],
    };
    let r = bench("train_step", 2, 10, || {
        std::hint::black_box(rt.train(&batch).unwrap());
    });
    println!("{}", BenchResult::markdown_header());
    println!("{}", r.to_markdown_row());
    println!(
        "\n(train graph: B={} T={} — {} params through Adam per step)",
        d.train_batch,
        d.seq_len,
        rt.manifest.param_count
    );
    let p = write_csv("micro_runtime", &csv);
    println!("csv: {}", p.display());
}
