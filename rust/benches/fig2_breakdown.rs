//! Figure 2: GPU hardware performance bottleneck breakdown.
//!
//! Reproduces the paper's idealization-ladder experiment on the real
//! kernel trace of our R2D2 training graph (paper-scale, extracted by
//! aot.py): idealize DRAM bandwidth → DRAM latency → L2 → SM occupancy
//! and attribute the recovered time to each component. Paper reference:
//! Math 57%, SM util 15%, DRAM BW 12%, remainder ≈16%.

use rlarch::report::figure::{ascii_bar, Table};
use rlarch::report::{bench, write_csv};
use rlarch::simarch::{synthetic_paper_train_trace, GpuModel, Idealize, TraceSet};
use std::path::Path;

fn main() {
    let gpu = GpuModel::new(rlarch::config::GpuModelConfig::default());

    // Real trace when artifacts exist; synthetic fallback otherwise.
    let trace = TraceSet::load(Path::new("artifacts"))
        .ok()
        .and_then(|ts| ts.find("train_paper_scale").cloned())
        .unwrap_or_else(|| {
            eprintln!("(artifacts missing: using the synthetic paper-scale trace)");
            synthetic_paper_train_trace(2, 80, 16)
        });

    println!(
        "# Fig. 2 — GPU bottleneck breakdown ({} kernels, {:.1} GFLOP, {:.2} GB)\n",
        trace.len(),
        trace.total_flops() / 1e9,
        trace.total_bytes() as f64 / 1e9
    );

    let b = gpu.breakdown(&trace);
    let mut t = Table::new(&["component", "ours", "", "paper"]);
    for (name, share, paper) in [
        ("Math (compute)", b.math, "57%"),
        ("SM utilization", b.sm_util, "15%"),
        ("DRAM bandwidth", b.dram_bw, "12%"),
        ("DRAM latency", b.dram_latency, "—"),
        ("L2", b.l2, "—"),
    ] {
        t.row(&[
            name.to_string(),
            format!("{:.1}%", share * 100.0),
            ascii_bar(share, 30),
            paper.to_string(),
        ]);
    }
    println!("{}", t.to_markdown());
    println!(
        "headline: idealizing everything but Math buys {:.2}x (< 2x — the \
         paper's Conclusion 1: the GPU uarch is well balanced).\n",
        1.0 / b.math
    );

    // Ladder rungs as modeled absolute times.
    let mut rungs = Table::new(&["rung", "modeled time", "speedup vs baseline"]);
    let t0 = gpu.trace_time(&trace, Idealize::NONE);
    for (name, ideal) in [
        ("baseline", Idealize::NONE),
        ("+∞ DRAM BW", Idealize { dram_bw: true, ..Idealize::NONE }),
        (
            "+0 DRAM latency",
            Idealize { dram_bw: true, dram_latency: true, ..Idealize::NONE },
        ),
        (
            "+ideal L2",
            Idealize { dram_bw: true, dram_latency: true, l2: true, ..Idealize::NONE },
        ),
        ("+perfect SM util (= Math)", Idealize::ALL),
    ] {
        let ti = gpu.trace_time(&trace, ideal);
        rungs.row(&[
            name.to_string(),
            format!("{:.2} ms", ti * 1e3),
            format!("{:.3}x", t0 / ti),
        ]);
    }
    println!("{}", rungs.to_markdown());

    // Simulator throughput itself (this bench is also a perf probe).
    let r = bench("breakdown_ladder", 3, 20, || {
        std::hint::black_box(gpu.breakdown(&trace));
    });
    println!("{}", rlarch::report::BenchResult::markdown_header());
    println!("{}", r.to_markdown_row());

    let mut csv = String::from("component,share\n");
    for (n, s) in [
        ("math", b.math),
        ("sm_util", b.sm_util),
        ("dram_bw", b.dram_bw),
        ("dram_latency", b.dram_latency),
        ("l2", b.l2),
    ] {
        csv.push_str(&format!("{n},{s}\n"));
    }
    let p = write_csv("fig2_breakdown", &csv);
    println!("\ncsv: {}", p.display());
}
