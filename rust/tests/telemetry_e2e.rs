//! End-to-end telemetry acceptance (ISSUE 6).
//!
//! Two properties:
//!
//! 1. **Non-perturbation**: with a span tracer installed (telemetry
//!    enabled), the instrumented actor loop must produce bit-for-bit
//!    the same replay stream as the default (telemetry-off) run —
//!    instrumentation observes the dataflow, it never steers it.
//! 2. **Emission**: an enabled full-system mock run writes a parseable
//!    Chrome trace containing the expected phase spans, a JSONL
//!    time-series carrying the live CPU/GPU-ratio gauge, and renders a
//!    Fig. 2-style phase-attribution table with `telemetry.model_drift`.

use rlarch::config::SystemConfig;
use rlarch::coordinator;
use rlarch::coordinator::actor::{run_actor, ActorArgs};
use rlarch::exec::ShutdownToken;
use rlarch::metrics::Registry;
use rlarch::policy::{LocalClient, PolicyClient};
use rlarch::replay::{ReplayConfig, SequenceReplay};
use rlarch::rl::Sequence;
use rlarch::runtime::{Backend, MockModel, ModelDims};
use rlarch::telemetry::{self, SpanKind, Tracer};
use rlarch::util::json::Value;
use std::path::PathBuf;
use std::sync::Arc;

/// Deterministic single-actor workload (mirrors the coordinator_e2e
/// equivalence config): 3 env slots, one thread, local inference.
fn equivalence_cfg() -> (SystemConfig, ModelDims) {
    let mut cfg = SystemConfig::default();
    cfg.env.name = "catch".into();
    cfg.env.step_cost_us = 0;
    cfg.env.frame_stack = 4;
    cfg.actors.num_actors = 1;
    cfg.actors.envs_per_actor = 3;
    cfg.learner.burn_in = 2;
    cfg.learner.unroll_len = 4;
    cfg.learner.seq_overlap = 2;
    cfg.batcher.max_batch = 2;
    cfg.batcher.batch_sizes = vec![1, 2];
    cfg.batcher.timeout_us = 200;
    let dims = ModelDims {
        obs_len: 400,
        hidden: 8,
        num_actions: 4,
        seq_len: 6,
        train_batch: 2,
    };
    (cfg, dims)
}

/// Run the actor loop against a given registry (with or without a
/// tracer installed) and return its replay stream.
fn run_traced_actor(
    cfg: &SystemConfig,
    dims: ModelDims,
    backend: &Backend,
    rounds: u64,
    metrics: Registry,
) -> Vec<Arc<Sequence>> {
    let replay = Arc::new(SequenceReplay::new(ReplayConfig {
        capacity: 4_096,
        ..Default::default()
    }));
    let policy: Box<dyn PolicyClient> = Box::new(LocalClient::new(
        backend.clone(),
        cfg.batcher.max_batch,
        dims,
        &metrics,
    ));
    run_actor(ActorArgs {
        id: 0,
        cfg: cfg.clone(),
        dims,
        policy,
        replay: replay.clone(),
        metrics,
        shutdown: ShutdownToken::new(),
        max_rounds: Some(rounds),
    })
    .unwrap();
    replay.snapshot()
}

#[test]
fn traced_actor_run_is_bit_for_bit_identical_to_untraced() {
    let (cfg, dims) = equivalence_cfg();
    let rounds = 60u64;
    let backend = Backend::Mock(Arc::new(MockModel::new(dims, 11)));

    // Golden: the default registry — no tracer, inert recorders.
    let golden = run_traced_actor(&cfg, dims, &backend, rounds, Registry::new());
    assert!(!golden.is_empty(), "workload produced no sequences");

    // Traced: same workload with live span recorders on every phase.
    let metrics = Registry::new();
    let tracer = Tracer::new(1_024);
    metrics.install_tracer(tracer.clone());
    let traced = run_traced_actor(&cfg, dims, &backend, rounds, metrics);

    assert_eq!(traced.len(), golden.len(), "sequence count diverged");
    for (i, (a, b)) in traced.iter().zip(&golden).enumerate() {
        assert_eq!(a, b, "sequence {i} diverged under tracing");
    }
    // And the tracer actually observed the run: env-step and policy
    // spans from the actor thread.
    assert!(tracer.span_count() > 0, "no spans recorded");
    let kinds: Vec<SpanKind> = tracer
        .rings()
        .iter()
        .flat_map(|r| r.collect())
        .map(|s| s.kind)
        .collect();
    for want in [SpanKind::EnvStep, SpanKind::PolicySubmit, SpanKind::PolicyWait]
    {
        assert!(
            kinds.contains(&want),
            "missing {} spans in {kinds:?}",
            want.name()
        );
    }
}

fn temp_path(name: &str) -> PathBuf {
    let dir = std::env::temp_dir().join("rlarch_telemetry_e2e");
    std::fs::create_dir_all(&dir).unwrap();
    dir.join(name)
}

#[test]
fn enabled_run_emits_trace_jsonl_and_phase_attribution() {
    let trace_path = temp_path("trace.json");
    let metrics_path = temp_path("metrics.jsonl");
    let _ = std::fs::remove_file(&trace_path);
    let _ = std::fs::remove_file(&metrics_path);

    let mut cfg = SystemConfig::default();
    cfg.env.name = "catch".into();
    cfg.env.frame_stack = 4;
    cfg.actors.num_actors = 4;
    cfg.learner.burn_in = 2;
    cfg.learner.unroll_len = 4;
    cfg.learner.seq_overlap = 2;
    cfg.learner.train_batch = 4;
    cfg.learner.min_replay = 8;
    cfg.learner.max_steps = 30;
    cfg.learner.target_update_interval = 10;
    cfg.replay.capacity = 512;
    cfg.batcher.max_batch = 8;
    cfg.batcher.batch_sizes = vec![1, 8];
    cfg.batcher.timeout_us = 1_000;
    cfg.telemetry.trace_out = trace_path.to_str().unwrap().to_string();
    cfg.telemetry.metrics_out = metrics_path.to_str().unwrap().to_string();
    cfg.telemetry.snapshot_interval_ms = 5;
    let dims = ModelDims {
        obs_len: 400,
        hidden: 8,
        num_actions: 4,
        seq_len: 6,
        train_batch: 4,
    };
    let backend = Backend::Mock(Arc::new(MockModel::new(dims, 11)));
    let metrics = Registry::new();
    let report = coordinator::run(&cfg, backend, metrics.clone()).unwrap();
    assert_eq!(report.learner.steps, 30);
    assert!(report.first_error.is_none(), "{:?}", report.first_error);

    // Chrome trace: parseable, and every pipeline phase shows up.
    let events =
        telemetry::validate_trace_file(trace_path.to_str().unwrap()).unwrap();
    assert!(events > 0);
    let doc =
        Value::parse(&std::fs::read_to_string(&trace_path).unwrap()).unwrap();
    let names: Vec<String> = doc
        .get("traceEvents")
        .and_then(|e| e.as_arr())
        .unwrap()
        .iter()
        .filter_map(|e| e.get("name").and_then(|n| n.as_str()))
        .map(str::to_string)
        .collect();
    for phase in [
        "env_step",
        "policy_submit",
        "policy_wait",
        "batcher_collect",
        "batcher_launch",
        "replay_insert",
        "replay_sample",
        "learner_assemble",
        "learner_train",
    ] {
        assert!(names.iter().any(|n| n == phase), "trace lacks {phase} spans");
    }

    // JSONL time-series: parseable, and the guaranteed final tick
    // carries the live CPU/GPU-ratio proxy plus the other derived
    // gauges.
    let samples =
        telemetry::validate_metrics_file(metrics_path.to_str().unwrap())
            .unwrap();
    assert!(samples >= 1);
    let text = std::fs::read_to_string(&metrics_path).unwrap();
    let last = Value::parse(text.lines().rev().find(|l| !l.trim().is_empty()).unwrap())
        .unwrap();
    let ratio = last
        .get(telemetry::CPU_GPU_RATIO)
        .and_then(|v| v.as_f64())
        .expect("final sample lacks telemetry.cpu_gpu_ratio");
    assert!(ratio > 0.0 && ratio.is_finite(), "ratio {ratio}");
    assert!(last.get("telemetry.steps_per_sec").is_some());
    assert!(last.get("actor.env_seconds.sum").is_some());
    assert!(last.get("batcher.queue_wakeups").is_some());
    assert_eq!(metrics.gauge(telemetry::CPU_GPU_RATIO).get(), ratio);

    // Phase attribution vs the architectural model, drift exported.
    let model = rlarch::simarch::default_system(
        rlarch::simarch::synthetic_paper_trace(1, 1, 64),
        rlarch::simarch::synthetic_paper_train_trace(2, 80, 16),
    );
    let table = telemetry::attribution_report(
        &metrics,
        Some(&model),
        cfg.actors.num_actors,
    )
    .expect("no attribution despite recorded phases");
    for needle in ["env", "infer", "train", "replay", "telemetry.model_drift"] {
        assert!(table.contains(needle), "attribution table lacks {needle}:\n{table}");
    }
    let drift = metrics.gauge(telemetry::MODEL_DRIFT).get();
    assert!((0.0..=1.0).contains(&drift), "drift {drift}");
}

#[test]
fn disabled_run_writes_no_telemetry_files() {
    // Defaults off: the coordinator must not create trace/metrics files
    // (their paths are empty — nothing to write) and the wakeup counter
    // still counts (it is unconditional plumbing, not telemetry-gated;
    // with doorbell batching it counts notifies actually issued, and the
    // batcher parks between round-trips so a run always rings it).
    let mut cfg = SystemConfig::default();
    cfg.env.name = "catch".into();
    cfg.env.frame_stack = 4;
    cfg.actors.num_actors = 1;
    cfg.learner.burn_in = 2;
    cfg.learner.unroll_len = 4;
    cfg.learner.seq_overlap = 2;
    cfg.learner.train_batch = 2;
    cfg.learner.min_replay = 4;
    cfg.learner.max_steps = 5;
    cfg.replay.capacity = 256;
    cfg.batcher.max_batch = 2;
    cfg.batcher.batch_sizes = vec![1, 2];
    let dims = ModelDims {
        obs_len: 400,
        hidden: 8,
        num_actions: 4,
        seq_len: 6,
        train_batch: 2,
    };
    assert!(!cfg.telemetry.enabled());
    let backend = Backend::Mock(Arc::new(MockModel::new(dims, 7)));
    let metrics = Registry::new();
    let report = coordinator::run(&cfg, backend, metrics.clone()).unwrap();
    assert_eq!(report.learner.steps, 5);
    assert!(metrics.tracer().is_none(), "tracer installed on a default run");
    assert!(
        metrics.counter("batcher.queue_wakeups").get() > 0,
        "doorbell counter must count regardless of telemetry"
    );
}
