//! Cross-module property tests (the in-repo quickcheck framework):
//! batching, replay, sequence slicing, scheduler conservation, and the
//! Rust/loss-layer math mirrors.

use rlarch::config::CpuModelConfig;
use rlarch::replay::{ReplayConfig, SequenceReplay, SumTree};
use rlarch::rl::{Sequence, SequenceBuilder, SequencePool, Transition};
use rlarch::simarch::CpuModel;
use rlarch::util::prng::Pcg32;
use rlarch::util::quickcheck::{forall, prop_assert, prop_assert_eq, prop_close};
use std::sync::Arc;

/// Verbatim replica of the seed `SequenceBuilder` (pre-arena): a
/// `Vec<Transition>` ring sliced by an `emit` that allocates four fresh
/// buffers per sequence. The golden reference the arena-backed builder
/// must match byte for byte.
struct SeedBuilder {
    seq_len: usize,
    overlap: usize,
    obs_len: usize,
    actor_id: usize,
    buf: Vec<Transition>,
}

impl SeedBuilder {
    fn new(seq_len: usize, overlap: usize, obs_len: usize, actor_id: usize) -> Self {
        assert!(overlap < seq_len);
        Self {
            seq_len,
            overlap,
            obs_len,
            actor_id,
            buf: Vec::with_capacity(seq_len),
        }
    }

    fn push(&mut self, t: Transition) -> Option<Sequence> {
        let terminal = t.discount == 0.0;
        self.buf.push(t);
        if self.buf.len() == self.seq_len {
            let seq = self.emit(self.seq_len);
            self.buf.drain(..self.seq_len - self.overlap);
            return Some(seq);
        }
        if terminal {
            let seq = self.emit(self.buf.len());
            self.buf.clear();
            return Some(seq);
        }
        None
    }

    fn flush(&mut self) -> Option<Sequence> {
        if self.buf.is_empty() {
            return None;
        }
        let seq = self.emit(self.buf.len());
        self.buf.clear();
        Some(seq)
    }

    fn emit(&self, valid: usize) -> Sequence {
        let t_len = self.seq_len;
        let mut obs = vec![0.0f32; t_len * self.obs_len];
        let mut actions = vec![0i32; t_len];
        let mut rewards = vec![0.0f32; t_len];
        let mut discounts = vec![0.0f32; t_len];
        for (i, tr) in self.buf.iter().take(valid).enumerate() {
            obs[i * self.obs_len..(i + 1) * self.obs_len].copy_from_slice(&tr.obs);
            actions[i] = tr.action;
            rewards[i] = tr.reward;
            discounts[i] = tr.discount;
        }
        Sequence {
            obs,
            actions,
            rewards,
            discounts,
            h0: self.buf[0].h.clone(),
            c0: self.buf[0].c.clone(),
            actor_id: self.actor_id,
            valid_len: valid,
        }
    }
}

#[test]
fn prop_pooled_slice_builder_matches_seed_push_path_byte_for_byte() {
    // The tentpole equivalence: the arena-backed builder fed borrowed
    // rows through a recycling pool must emit sequences byte-identical
    // to the seed's owned-Transition path across randomized episode
    // lengths, terminals, overlaps, and flush points.
    forall(60, |g| {
        let seq_len = g.usize(2..12);
        let overlap = g.usize(0..seq_len);
        let obs_len = g.usize(1..6);
        let hidden = g.usize(1..5);
        let actor_id = g.usize(0..9);
        let pool = Arc::new(SequencePool::with_capacity(64));
        let mut golden = SeedBuilder::new(seq_len, overlap, obs_len, actor_id);
        let mut arena =
            SequenceBuilder::new(seq_len, overlap, obs_len, hidden, actor_id)
                .with_pool(pool.clone());
        let n = g.usize(1..250);
        let mut emitted = 0u32;
        for i in 0..n {
            let terminal = g.chance(0.08);
            let obs: Vec<f32> =
                (0..obs_len).map(|k| (i * 7 + k) as f32 * 0.25).collect();
            let h: Vec<f32> =
                (0..hidden).map(|k| (i * 3 + k) as f32 * 0.5).collect();
            let c: Vec<f32> =
                (0..hidden).map(|k| (i * 5 + k) as f32 * -0.5).collect();
            let reward = i as f32 * 0.125;
            let discount = if terminal { 0.0 } else { 0.93 };
            let a = arena.push_slices(&obs, i as i32, reward, discount, &h, &c);
            let b = golden.push(Transition {
                obs,
                action: i as i32,
                reward,
                discount,
                h,
                c,
            });
            match (a, b) {
                (Some(x), Some(y)) => {
                    prop_assert(
                        x == y,
                        &format!("sequence diverged at step {i}"),
                    )?;
                    emitted += 1;
                    // Recycle through the pool so later emits exercise
                    // reused (stale-content) buffers.
                    pool.put(x);
                }
                (None, None) => {}
                _ => return Err(format!("emit timing diverged at step {i}")),
            }
        }
        let fa = arena.flush();
        let fb = golden.flush();
        prop_assert(fa == fb, "flush diverged")?;
        prop_assert(
            arena.buffered() == golden.buf.len(),
            "buffered count diverged",
        )?;
        if emitted > 2 {
            prop_assert(pool.hits() > 0, "pool never recycled")?;
        }
        Ok(())
    });
}

#[test]
fn prop_sumtree_total_equals_leaf_sum_under_any_op_sequence() {
    forall(150, |g| {
        let cap = g.usize(1..128);
        let mut t = SumTree::new(cap);
        let mut shadow = vec![0.0f64; t.capacity()];
        for _ in 0..g.usize(0..256) {
            let i = g.usize(0..t.capacity());
            let p = g.f64(0.0..5.0);
            t.set(i, p);
            shadow[i] = p;
        }
        prop_close(t.total(), shadow.iter().sum(), 1e-9)
    });
}

#[test]
fn prop_replay_sampled_slots_always_hold_sequences() {
    forall(60, |g| {
        // Random shard counts too: capacity is drawn as a multiple of
        // the shard count so striping is always well-formed.
        let shards = g.usize(1..5);
        let cap = shards * g.usize(4..32);
        let r = SequenceReplay::new(ReplayConfig {
            capacity: cap,
            alpha: g.f64(0.0..1.0),
            min_priority: 1e-3,
            shards,
        });
        let n_add = g.usize(1..200);
        for i in 0..n_add {
            r.add(Sequence {
                obs: vec![i as f32; 4],
                actions: vec![0; 2],
                rewards: vec![0.0; 2],
                discounts: vec![0.9; 2],
                h0: vec![0.0; 2],
                c0: vec![0.0; 2],
                actor_id: 0,
                valid_len: 2,
            });
        }
        let batch = g.usize(1..8).min(r.len());
        if batch == 0 {
            return Ok(());
        }
        let mut rng = Pcg32::seeded(g.u64(0..u64::MAX - 1));
        if let Some(s) = r.sample(batch, &mut rng) {
            prop_assert(s.sequences.len() == batch, "batch size")?;
            // Update with arbitrary priorities never panics / corrupts.
            let prios: Vec<f32> =
                (0..batch).map(|_| g.f64(0.0..100.0) as f32).collect();
            r.update_priorities(&s.slots, &s.generations, &prios);
            let mut rng2 = Pcg32::seeded(1);
            prop_assert(r.sample(batch, &mut rng2).is_some(), "resample")?;
        }
        Ok(())
    });
}

#[test]
fn prop_sequence_builder_conserves_transitions() {
    // Every non-overlap transition appears in exactly one emitted
    // sequence; overlap transitions appear in at most two.
    forall(80, |g| {
        let seq_len = g.usize(2..12);
        let overlap = g.usize(0..seq_len);
        let mut b = SequenceBuilder::new(seq_len, overlap, 1, 1, 0);
        let n = g.usize(1..300);
        let mut emitted: Vec<Sequence> = Vec::new();
        for i in 0..n {
            let terminal = g.chance(0.05);
            if let Some(s) = b.push(Transition {
                obs: vec![i as f32],
                action: i as i32,
                reward: 0.0,
                discount: if terminal { 0.0 } else { 0.9 },
                h: vec![0.0],
                c: vec![0.0],
            }) {
                emitted.push(s);
            }
        }
        if let Some(s) = b.flush() {
            emitted.push(s);
        }
        // Count appearances of each step index across valid regions.
        let mut counts = vec![0u32; n];
        for s in &emitted {
            for k in 0..s.valid_len {
                counts[s.actions[k] as usize] += 1;
            }
        }
        // A transition can appear in ceil(seq_len / stride) consecutive
        // sequences (stride = seq_len - overlap), +1 for a terminal flush.
        let stride = seq_len - overlap;
        let max_dup = (seq_len.div_ceil(stride) + 1) as u32;
        for (i, c) in counts.iter().enumerate() {
            prop_assert(*c >= 1, &format!("transition {i} lost"))?;
            prop_assert(
                *c <= max_dup,
                &format!("transition {i} appeared {c}x (max {max_dup})"),
            )?;
        }
        Ok(())
    });
}

#[test]
fn prop_env_suite_contract_frames_actions_determinism() {
    // Registry-driven contract for every registered environment:
    //   * frames are always GRID*GRID floats in [0, 1],
    //   * real_actions() is in 1..=NUM_ACTIONS,
    //   * trajectories are deterministic under a fixed seed.
    use rlarch::env::{make_env, new_frame, registered_envs, GRID, NUM_ACTIONS};
    forall(25, |g| {
        for name in registered_envs() {
            let seed = g.u64(0..u64::MAX - 1);
            let mut env = make_env(name, seed).map_err(|e| e.to_string())?;
            let mut twin = make_env(name, seed).map_err(|e| e.to_string())?;
            let ra = env.real_actions();
            prop_assert(
                (1..=NUM_ACTIONS).contains(&ra),
                &format!("{name}: real_actions {ra} outside 1..={NUM_ACTIONS}"),
            )?;

            let mut frame = new_frame();
            let mut frame2 = new_frame();
            env.reset(&mut frame);
            twin.reset(&mut frame2);
            prop_assert(frame == frame2, &format!("{name}: reset nondeterministic"))?;

            let steps = g.usize(20..120);
            for i in 0..steps {
                let a = g.usize(0..NUM_ACTIONS);
                let s1 = env.step(a, &mut frame);
                let s2 = twin.step(a, &mut frame2);
                prop_assert(
                    s1 == s2,
                    &format!("{name}: step {i} diverged under same seed+actions"),
                )?;
                prop_assert(
                    frame == frame2,
                    &format!("{name}: frame {i} diverged under same seed+actions"),
                )?;
                prop_assert(
                    frame.len() == GRID * GRID,
                    &format!("{name}: frame length {}", frame.len()),
                )?;
                for &v in &frame {
                    prop_assert(
                        (0.0..=1.0).contains(&v),
                        &format!("{name}: frame value {v} out of [0,1] at step {i}"),
                    )?;
                }
                if s1.done {
                    env.reset(&mut frame);
                    twin.reset(&mut frame2);
                }
            }
        }
        Ok(())
    });
}

#[test]
fn prop_vecenv_slots_equal_independent_wrapped_envs() {
    // The vectorized engine is observationally equivalent to E
    // independent single-env instances over any action sequence.
    use rlarch::config::EnvConfig;
    use rlarch::env::wrappers::Wrapped;
    use rlarch::vecenv::VecEnv;
    forall(15, |g| {
        let name = *g.pick(&["catch", "grid_pong", "breakout", "nav_maze"]);
        let cfg = EnvConfig {
            name: name.to_string(),
            frame_stack: g.usize(1..5),
            sticky_action_prob: g.f64(0.0..0.5),
            max_episode_len: g.usize(10..80),
            step_cost_us: 0,
            seed: g.u64(0..1 << 40),
            batch_native: false,
        };
        let e = g.usize(1..5);
        let base = g.u64(1..1 << 20);
        let mut venv = VecEnv::from_config(&cfg, e, base).map_err(|x| x.to_string())?;
        let mut solos: Vec<Wrapped> = (0..e)
            .map(|i| Wrapped::from_config(&cfg, base + i as u64).unwrap())
            .collect();
        let obs_len = venv.obs_len();
        let mut obs = venv.new_obs_batch();
        venv.reset_all(&mut obs);
        let mut obs_s = vec![vec![0.0f32; obs_len]; e];
        for (s, o) in solos.iter_mut().zip(&mut obs_s) {
            s.reset(o);
        }
        for i in 0..g.usize(10..150) {
            let actions: Vec<usize> = (0..e).map(|_| g.usize(0..4)).collect();
            let steps = venv.step_all(&actions, &mut obs).to_vec();
            for k in 0..e {
                let ss = solos[k].step(actions[k], &mut obs_s[k]);
                prop_assert(
                    steps[k] == ss,
                    &format!("{name}: slot {k} step {i} diverged"),
                )?;
                prop_assert(
                    obs[k * obs_len..(k + 1) * obs_len] == obs_s[k][..],
                    &format!("{name}: slot {k} obs {i} diverged"),
                )?;
            }
        }
        Ok(())
    });
}

#[test]
fn prop_soa_engine_equals_per_slot_wrapped_byte_for_byte() {
    // The batch-native SoA engine (env::soa) must be byte-identical to
    // E independent per-slot `Wrapped` replicas with the same seed
    // layout, across random envs, seeds, frame-stack depths, sticky
    // probabilities, episode lengths (auto-resets included), and action
    // sequences: obs rows, rewards, done flags, and episode stats.
    use rlarch::config::EnvConfig;
    use rlarch::env::soa::make_batch_env;
    use rlarch::env::wrappers::Wrapped;
    forall(15, |g| {
        let name = *g.pick(&["catch", "grid_pong", "breakout", "nav_maze"]);
        let cfg = EnvConfig {
            name: name.to_string(),
            frame_stack: g.usize(1..5),
            sticky_action_prob: g.f64(0.0..0.5),
            max_episode_len: g.usize(10..80),
            step_cost_us: 0,
            seed: g.u64(0..1 << 40),
            batch_native: true,
        };
        let e = g.usize(1..5);
        let base = g.u64(1..1 << 20);
        let mut soa = make_batch_env(&cfg, e, base).map_err(|x| x.to_string())?;
        let mut solos: Vec<Wrapped> = (0..e)
            .map(|i| Wrapped::from_config(&cfg, base + i as u64).unwrap())
            .collect();
        let obs_len = soa.obs_len();
        let mut obs = vec![0.0f32; e * obs_len];
        soa.reset_all(&mut obs);
        let mut obs_s = vec![vec![0.0f32; obs_len]; e];
        for (s, o) in solos.iter_mut().zip(&mut obs_s) {
            s.reset(o);
        }
        for k in 0..e {
            prop_assert(
                obs[k * obs_len..(k + 1) * obs_len] == obs_s[k][..],
                &format!("{name}: slot {k} reset obs diverged"),
            )?;
        }
        let mut steps = Vec::with_capacity(e);
        for i in 0..g.usize(10..150) {
            let actions: Vec<usize> = (0..e).map(|_| g.usize(0..4)).collect();
            steps.clear();
            soa.step_all(&actions, &mut obs, &mut steps);
            for k in 0..e {
                let ss = solos[k].step(actions[k], &mut obs_s[k]);
                prop_assert(
                    steps[k] == ss,
                    &format!("{name}: slot {k} step {i} diverged"),
                )?;
                prop_assert(
                    obs[k * obs_len..(k + 1) * obs_len] == obs_s[k][..],
                    &format!("{name}: slot {k} obs {i} diverged"),
                )?;
            }
        }
        prop_assert(
            soa.total_steps() == solos.iter().map(|s| s.total_steps).sum::<u64>(),
            &format!("{name}: total_steps diverged"),
        )?;
        prop_assert(
            soa.episodes_completed() == solos.iter().map(|s| s.episodes_completed).sum::<u64>(),
            &format!("{name}: episodes_completed diverged"),
        )?;
        for (k, s) in solos.iter().enumerate() {
            prop_assert(
                soa.last_return(k) == s.last_return,
                &format!("{name}: slot {k} last_return diverged"),
            )?;
        }
        Ok(())
    });
}

#[test]
fn prop_cpu_capacity_monotone_and_bounded() {
    forall(100, |g| {
        let threads = g.usize(2..256) & !1; // even
        let m = CpuModel::new(CpuModelConfig {
            hw_threads: threads,
            ..Default::default()
        });
        let a = g.usize(1..512);
        let b = a + g.usize(1..64);
        let ca = m.capacity(a);
        let cb = m.capacity(b);
        // Monotone up to hw_threads; never exceeds SMT-peak.
        if b <= threads {
            prop_assert(cb >= ca - 1e-9, "capacity must grow with actors")?;
        }
        let peak = (threads / 2) as f64 * 2.0 * 0.65;
        prop_assert(ca <= peak + 1e-9, "capacity above SMT peak")?;
        prop_assert(ca > 0.0, "capacity positive")
    });
}

#[test]
fn prop_value_rescale_mirrors_are_inverse_and_monotone() {
    forall(200, |g| {
        let x = g.f64(-1e5..1e5);
        let y = rlarch::rl::value_rescale(x, 1e-3);
        prop_close(rlarch::rl::value_rescale_inv(y, 1e-3), x, 1e-6)?;
        let x2 = x + g.f64(0.001..10.0);
        let y2 = rlarch::rl::value_rescale(x2, 1e-3);
        prop_assert(y2 > y, "monotone")
    });
}

#[test]
fn prop_epsilon_greedy_distribution_bounds() {
    forall(40, |g| {
        let eps = g.f64(0.0..1.0);
        let q = vec![0.0f32, 1.0, 0.0];
        let mut rng = Pcg32::seeded(g.u64(0..u64::MAX - 1));
        let n = 4000;
        let greedy_hits = (0..n)
            .filter(|_| rlarch::rl::epsilon_greedy(&q, eps, &mut rng) == 1)
            .count() as f64
            / n as f64;
        // Greedy action frequency = (1 - eps) + eps/|A|, within noise.
        let expect = (1.0 - eps) + eps / 3.0;
        prop_close(greedy_hits, expect, 0.1)
    });
}

#[test]
fn prop_faults_spec_roundtrips_and_rejects_malformed() {
    // `--faults` spec parsing (DESIGN.md §15/§16): a generated spec
    // over every key parses back to exactly the values it encodes
    // (whitespace-tolerant), and malformed input is rejected with the
    // offending token named — never a panic.
    use rlarch::config::FaultsConfig;
    forall(120, |g| {
        let expect = FaultsConfig {
            seed: g.u64(0..1 << 50), // f64-exact: the spec parses as f64
            drop_rate: g.f64(0.0..1.0),
            delay_rate: g.f64(0.0..1.0),
            delay_ms: g.u64(0..10_000),
            truncate_rate: g.f64(0.0..1.0),
            corrupt_rate: g.f64(0.0..1.0),
            kill_rate: g.f64(0.0..1.0),
            stall_rate: g.f64(0.0..1.0),
            stall_ms: g.u64(0..10_000),
            panic_actor: g.i64(-1..8), // -1 = disabled
            panic_at_step: g.u64(1..100),
        };
        let kvs = [
            ("seed", expect.seed.to_string()),
            ("drop_rate", expect.drop_rate.to_string()),
            ("delay_rate", expect.delay_rate.to_string()),
            ("delay_ms", expect.delay_ms.to_string()),
            ("truncate_rate", expect.truncate_rate.to_string()),
            ("corrupt_rate", expect.corrupt_rate.to_string()),
            ("kill_rate", expect.kill_rate.to_string()),
            ("stall_rate", expect.stall_rate.to_string()),
            ("stall_ms", expect.stall_ms.to_string()),
            ("panic_actor", expect.panic_actor.to_string()),
            ("panic_at_step", expect.panic_at_step.to_string()),
        ];
        let pad = if g.chance(0.5) { " " } else { "" };
        let spec = kvs
            .iter()
            .map(|(k, v)| format!("{pad}{k}{pad}={pad}{v}{pad}"))
            .collect::<Vec<_>>()
            .join(",");
        let cfg =
            FaultsConfig::from_spec(&spec).map_err(|e| e.to_string())?;
        prop_assert_eq(cfg, expect)?;

        // Malformed specs name the offending token. The junk alphabet
        // holds no `=`, no digits, and no valid key.
        let junk: String = (0..g.usize(1..7))
            .map(|_| *g.pick(&['x', 'q', 'Z', '#', '~', '@']))
            .collect();
        let e = FaultsConfig::from_spec(&junk).unwrap_err().to_string();
        prop_assert(
            e.contains("want key=value") && e.contains(&junk),
            &format!("missing `=` diagnosed: {e}"),
        )?;
        let e = FaultsConfig::from_spec(&format!("drop_rate={junk}"))
            .unwrap_err()
            .to_string();
        prop_assert(
            e.contains("bad number"),
            &format!("bad number diagnosed: {e}"),
        )?;
        let e = FaultsConfig::from_spec(&format!("{junk}=1"))
            .unwrap_err()
            .to_string();
        prop_assert(
            e.contains("unknown faults spec key") && e.contains(&junk),
            &format!("unknown key named: {e}"),
        )?;
        let e = FaultsConfig::from_spec("drop_rate=1.5")
            .unwrap_err()
            .to_string();
        prop_assert(e.contains("[0, 1]"), &format!("range enforced: {e}"))
    });
}

#[test]
fn prop_control_parse_line_never_panics_and_errors_name_tokens() {
    // The serve control-socket parser (DESIGN.md §16): arbitrary junk
    // lines never panic and always name the offending token; every
    // well-formed command round-trips, tolerates padding, and rejects
    // trailing tokens by name.
    use rlarch::serve::control::{parse_line, Command};
    const JUNK: &[char] = &[
        'a', 'h', 'l', 't', 'x', '0', '7', '-', '_', '/', '.', '#', '!',
    ];
    const KNOWN: [&str; 5] = ["health", "ready", "stats", "shutdown", "reload"];
    forall(250, |g| {
        let words: Vec<String> = (0..g.usize(0..4))
            .map(|_| (0..g.usize(1..9)).map(|_| *g.pick(JUNK)).collect())
            .collect();
        let line = words.join(" ");
        match parse_line(&line) {
            Err(e) => match words.first() {
                None => prop_assert(e == "empty command", &e)?,
                Some(head) if !KNOWN.contains(&head.as_str()) => {
                    prop_assert(
                        e.contains(head.as_str()),
                        &format!("error `{e}` must name `{head}`"),
                    )?;
                }
                Some(_) => {} // known head, argument error
            },
            Ok(_) => prop_assert(
                KNOWN.contains(&words[0].as_str()),
                &format!("garbage `{line}` must not parse"),
            )?,
        }

        let dir = format!("/tmp/ck{}", g.usize(0..100));
        let cases = [
            ("health".to_string(), Command::Health),
            ("ready".to_string(), Command::Ready),
            ("stats".to_string(), Command::Stats),
            ("shutdown".to_string(), Command::Shutdown),
            (format!("reload {dir}"), Command::Reload(dir.clone())),
        ];
        for (line, want) in &cases {
            prop_assert(
                parse_line(line).as_ref() == Ok(want),
                &format!("`{line}` must parse"),
            )?;
            prop_assert(
                parse_line(&format!("  {line}  ")).as_ref() == Ok(want),
                "whitespace-padded command must parse",
            )?;
            let e = parse_line(&format!("{line} bogus")).unwrap_err();
            prop_assert(
                e.contains("bogus"),
                &format!("trailing-token error must name it: {e}"),
            )?;
        }
        let e = parse_line("reload").unwrap_err();
        prop_assert(e.contains("reload <dir>"), &e)
    });
}

#[test]
fn prop_gpu_idealization_never_slows_a_trace() {
    use rlarch::simarch::{synthetic_train_trace, GpuModel, Idealize};
    forall(60, |g| {
        let gpu = GpuModel::new(rlarch::config::GpuModelConfig::default());
        let trace = synthetic_train_trace(g.u64(0..1 << 32), g.usize(1..12),
                                          g.usize(1..128));
        let t0 = gpu.trace_time(&trace, Idealize::NONE);
        for ideal in [
            Idealize { dram_bw: true, ..Idealize::NONE },
            Idealize { dram_bw: true, dram_latency: true, ..Idealize::NONE },
            Idealize::ALL,
        ] {
            let ti = gpu.trace_time(&trace, ideal);
            prop_assert(ti <= t0 * (1.0 + 1e-9), "idealization slowed trace")?;
            prop_assert(ti > 0.0, "time must stay positive")?;
        }
        Ok(())
    });
}
