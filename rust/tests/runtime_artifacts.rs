//! Integration: load the real AOT artifacts and execute them via PJRT.
//! Skipped (with a message) when `make artifacts` has not run.

use rlarch::runtime::{InferRequest, TrainBatch, XlaRuntime};
use std::path::{Path, PathBuf};

fn artifacts_dir() -> Option<PathBuf> {
    let dir = Path::new(env!("CARGO_MANIFEST_DIR")).join("artifacts");
    dir.join("manifest.json").exists().then_some(dir)
}

macro_rules! require_artifacts {
    () => {
        match artifacts_dir() {
            Some(d) => d,
            None => {
                eprintln!("skipping: run `make artifacts` first");
                return;
            }
        }
    };
}

#[test]
#[ignore = "requires AOT artifacts (make artifacts) and a PJRT-enabled xla crate; the vendored host-only shim cannot execute HLO"]
fn infer_executes_and_shapes_match() {
    let dir = require_artifacts!();
    let rt = XlaRuntime::load(&dir, Some(&[1, 8]), false).unwrap();
    let d = rt.dims();
    let req = InferRequest {
        n: 3, // pads up to the b=8 artifact
        h: vec![0.0; 3 * d.hidden],
        c: vec![0.0; 3 * d.hidden],
        obs: vec![0.3; 3 * d.obs_len],
    };
    let out = rt.infer(&req).unwrap();
    assert_eq!(out.q.len(), 3 * d.num_actions);
    assert_eq!(out.h.len(), 3 * d.hidden);
    assert!(out.q.iter().all(|x| x.is_finite()));
    // Identical rows in, identical rows out (padding must not leak).
    assert_eq!(out.q[..d.num_actions], out.q[d.num_actions..2 * d.num_actions]);
}

#[test]
#[ignore = "requires AOT artifacts (make artifacts) and a PJRT-enabled xla crate; the vendored host-only shim cannot execute HLO"]
fn infer_batch_padding_consistent_with_exact_batch() {
    let dir = require_artifacts!();
    let rt = XlaRuntime::load(&dir, Some(&[1, 8]), false).unwrap();
    let d = rt.dims();
    let obs: Vec<f32> = (0..d.obs_len).map(|i| (i % 7) as f32 / 7.0).collect();
    let one = rt
        .infer(&InferRequest {
            n: 1,
            h: vec![0.1; d.hidden],
            c: vec![0.2; d.hidden],
            obs: obs.clone(),
        })
        .unwrap();
    // Same row inside a padded batch-of-8 request.
    let mut h = vec![0.0; 5 * d.hidden];
    let mut c = vec![0.0; 5 * d.hidden];
    let mut o = vec![0.0; 5 * d.obs_len];
    h[2 * d.hidden..3 * d.hidden].fill(0.1);
    c[2 * d.hidden..3 * d.hidden].fill(0.2);
    o[2 * d.obs_len..3 * d.obs_len].copy_from_slice(&obs);
    let five = rt.infer(&InferRequest { n: 5, h, c, obs: o }).unwrap();
    for a in 0..d.num_actions {
        let diff = (five.q[2 * d.num_actions + a] - one.q[a]).abs();
        assert!(diff < 1e-4, "action {a}: {diff}");
    }
}

#[test]
#[ignore = "requires AOT artifacts (make artifacts) and a PJRT-enabled xla crate; the vendored host-only shim cannot execute HLO"]
fn train_step_runs_and_loss_decreases_on_fixed_batch() {
    let dir = require_artifacts!();
    let mut rt = XlaRuntime::load(&dir, Some(&[1]), true).unwrap();
    let d = rt.dims();
    let bt = d.train_batch * d.seq_len;
    // Deterministic pseudo-random batch.
    let mut rng = rlarch::util::prng::Pcg32::seeded(99);
    let batch = TrainBatch {
        batch: d.train_batch,
        obs: (0..bt * d.obs_len).map(|_| rng.next_f32()).collect(),
        actions: (0..bt).map(|_| rng.index(d.num_actions) as i32).collect(),
        rewards: (0..bt).map(|_| rng.next_f32() - 0.3).collect(),
        discounts: vec![0.997; bt],
        h0: vec![0.0; d.train_batch * d.hidden],
        c0: vec![0.0; d.train_batch * d.hidden],
    };
    let r1 = rt.train(&batch).unwrap();
    assert!(r1.loss.is_finite() && r1.loss > 0.0);
    assert_eq!(r1.priorities.len(), d.train_batch);
    assert!(r1.priorities.iter().all(|p| *p >= 0.0));
    assert_eq!(r1.step, 1);
    let mut last = r1.loss;
    for _ in 0..4 {
        last = rt.train(&batch).unwrap().loss;
    }
    assert!(
        last < r1.loss,
        "loss should fall on a fixed batch: {} -> {last}",
        r1.loss
    );
    // Target sync + params-to-host snapshot work.
    rt.sync_target().unwrap();
    let params = rt.params_to_host().unwrap();
    assert_eq!(params.len(), rt.manifest.param_specs.len());
}

#[test]
#[ignore = "requires AOT artifacts (make artifacts) and a PJRT-enabled xla crate; the vendored host-only shim cannot execute HLO"]
fn vtrace_baseline_artifact_executes_via_raw_api() {
    let dir = require_artifacts!();
    let mut rt = XlaRuntime::load(&dir, Some(&[1]), false).unwrap();
    let m = &rt.manifest;
    let sig = match m.artifacts.get("vtrace_train") {
        Some(s) => s.clone(),
        None => {
            eprintln!("skipping: vtrace_train not in manifest");
            return;
        }
    };
    // Initial V-trace params/opt from the bundle; data tensors zeroed
    // with the shapes the manifest records.
    let bundle = rlarch::runtime::Bundle::read(&dir.join("init_params.bin")).unwrap();
    let vp = bundle.with_prefix("vp");
    let vo = bundle.with_prefix("vo");
    let n_state = vp.len() + vo.len();
    let mut inputs: Vec<rlarch::runtime::Tensor> = Vec::new();
    inputs.extend(vp.iter().cloned());
    inputs.extend(vo.iter().cloned());
    for (i, shape) in sig.inputs.iter().enumerate().skip(n_state) {
        // actions are the only integer input (rank-2 [B,T] at position
        // n_state+1 per the ABI); detect via manifest dtype is not stored
        // per-input here, so use the builder convention: index n_state+1.
        if i == n_state + 1 {
            inputs.push(rlarch::runtime::Tensor::from_i32(
                shape.clone(),
                vec![0; shape.iter().product()],
            ));
        } else {
            inputs.push(rlarch::runtime::Tensor::zeros_f32(shape.clone()));
        }
    }
    let outputs = rt.execute_raw("vtrace_train", &inputs).unwrap();
    // Outputs: params' + opt' + (loss, gnorm).
    assert_eq!(outputs.len(), n_state + 2);
    let loss = outputs[n_state].as_f32()[0];
    assert!(loss.is_finite(), "vtrace loss {loss}");
    // Param shapes preserved.
    for (o, p) in outputs.iter().zip(vp.iter()) {
        assert_eq!(o.shape, p.shape);
    }
}

#[test]
#[ignore = "requires AOT artifacts (make artifacts) and a PJRT-enabled xla crate; the vendored host-only shim cannot execute HLO"]
fn checkpoint_roundtrip_through_engine() {
    let dir = require_artifacts!();
    let mut rt = XlaRuntime::load(&dir, Some(&[1]), true).unwrap();
    let d = rt.dims();
    // One train step so params differ from init.
    let bt = d.train_batch * d.seq_len;
    let batch = TrainBatch {
        batch: d.train_batch,
        obs: vec![0.25; bt * d.obs_len],
        actions: vec![1; bt],
        rewards: vec![0.5; bt],
        discounts: vec![0.997; bt],
        h0: vec![0.0; d.train_batch * d.hidden],
        c0: vec![0.0; d.train_batch * d.hidden],
    };
    rt.train(&batch).unwrap();
    let snapshot = rt.params_to_host().unwrap();

    let tmp = std::env::temp_dir().join("rlarch_engine_ckpt.bin");
    rlarch::runtime::checkpoint::save_params(&tmp, &snapshot).unwrap();
    let loaded = rlarch::runtime::checkpoint::load_params(&tmp).unwrap();
    assert_eq!(loaded.len(), snapshot.len());

    // Restore into the engine and verify inference matches the snapshot.
    let req = InferRequest {
        n: 1,
        h: vec![0.0; d.hidden],
        c: vec![0.0; d.hidden],
        obs: vec![0.3; d.obs_len],
    };
    let q_before = rt.infer(&req).unwrap().q;
    rt.train(&batch).unwrap(); // drift params
    let q_drifted = rt.infer(&req).unwrap().q;
    assert_ne!(q_before, q_drifted, "training must change the policy");
    rt.params_from_host(&loaded).unwrap();
    let q_restored = rt.infer(&req).unwrap().q;
    for (a, b) in q_before.iter().zip(&q_restored) {
        assert!((a - b).abs() < 1e-6, "restore mismatch: {a} vs {b}");
    }
    let _ = std::fs::remove_file(&tmp);
}
