//! Fleet transport integration (DESIGN.md §14): wire-codec property
//! tests against random shapes and corrupted streams, loopback
//! UDS fleet equivalence against the in-process central path,
//! backpressure shedding, and the kill-and-reconnect lifecycle.
//!
//! The "processes" here are threads with separate metric registries and
//! shutdown tokens talking over a real Unix-domain socket — the same
//! frames, same handshake, same drain protocol as `rlarch serve` /
//! `rlarch actor --connect`, minus the fork.

use rlarch::config::{BatcherConfig, FaultsConfig, SystemConfig};
use rlarch::coordinator::actor::{run_actor, ActorArgs};
use rlarch::coordinator::{run_serve, run_worker, ActorStats, Batcher};
use rlarch::exec::ShutdownToken;
use rlarch::fault::{FaultPlan, FrameFault};
use rlarch::metrics::Registry;
use rlarch::policy::{CentralClient, PolicyClient};
use rlarch::replay::{ReplayConfig, SequenceReplay, SequenceSink};
use rlarch::rl::Sequence;
use rlarch::runtime::{Backend, MockModel, ModelDims};
use rlarch::serve::control::send_command;
use rlarch::serve::{AdmissionPolicy, CircuitBreaker, ServeGate};
use rlarch::transport::frame::{self, FrameKind, Role};
use rlarch::transport::{
    dial, Addr, FleetServer, FleetServerOpts, FrameReader, Listener, ReadOutcome,
    RemoteClient, RemoteClientOpts, RemoteIngest, Stream,
};
use rlarch::util::prng::Pcg32;
use std::collections::BTreeMap;
use std::io::Write;
use std::sync::atomic::Ordering;
use std::sync::Arc;
use std::time::{Duration, Instant};

// ---------------------------------------------------------------------------
// Codec property tests
// ---------------------------------------------------------------------------

fn strip_len(buf: &[u8]) -> &[u8] {
    let len = u32::from_le_bytes(buf[0..4].try_into().unwrap()) as usize;
    assert_eq!(len, buf.len() - 4, "length prefix covers the frame");
    &buf[4..]
}

#[test]
fn codec_roundtrips_random_rows_dims_and_tickets() {
    // Property: for random (rows, obs_len, hidden, num_actions, ticket,
    // slot0), encode → parse → decode is the identity on every field.
    let mut rng = Pcg32::seeded(0xF1EE7);
    let mut buf = Vec::new();
    for case in 0..200 {
        let rows = 1 + rng.index(32);
        let obs_len = 1 + rng.index(64);
        let hidden = 1 + rng.index(32);
        let na = 1 + rng.index(8);
        let ticket = rng.next_u64();
        let slot0 = rng.next_u32() >> 8;
        let fill = |n: usize, rng: &mut Pcg32| -> Vec<f32> {
            (0..n).map(|_| rng.next_f32() * 2.0 - 1.0).collect()
        };

        let obs = fill(rows * obs_len, &mut rng);
        let h = fill(rows * hidden, &mut rng);
        let c = fill(rows * hidden, &mut rng);
        frame::encode_submit(&mut buf, ticket, rows, &obs, &h, &c);
        let fr = strip_len(&buf);
        let hd = frame::parse_header(fr).unwrap();
        assert_eq!(
            (hd.kind, hd.ticket, hd.rows),
            (FrameKind::Submit, ticket, rows as u32),
            "case {case}"
        );
        let (mut o2, mut h2, mut c2) = (Vec::new(), Vec::new(), Vec::new());
        frame::decode_submit(
            frame::payload(fr),
            rows,
            obs_len,
            hidden,
            &mut o2,
            &mut h2,
            &mut c2,
        )
        .unwrap();
        assert_eq!((o2, h2, c2), (obs, h, c), "case {case}");

        let q = fill(rows * na, &mut rng);
        let qh = fill(rows * hidden, &mut rng);
        let qc = fill(rows * hidden, &mut rng);
        frame::encode_reply_ok(&mut buf, ticket, slot0, rows, &q, &qh, &qc);
        let fr = strip_len(&buf);
        let hd = frame::parse_header(fr).unwrap();
        assert_eq!((hd.ticket, hd.slot0, hd.rows), (ticket, slot0, rows as u32));
        let (mut q2, mut h2, mut c2) = (Vec::new(), Vec::new(), Vec::new());
        frame::decode_reply_ok(
            frame::payload(fr),
            rows,
            na,
            hidden,
            &mut q2,
            &mut h2,
            &mut c2,
        )
        .unwrap();
        assert_eq!((q2, h2, c2), (q, qh, qc), "case {case}");

        // A decode against the WRONG dims must fail, never mis-scatter
        // (payload length disagrees with rows * dims).
        let (mut o3, mut h3, mut c3) = (Vec::new(), Vec::new(), Vec::new());
        frame::encode_submit(&mut buf, ticket, rows, &obs, &h, &c);
        let fr = strip_len(&buf);
        assert!(
            frame::decode_submit(
                frame::payload(fr),
                rows,
                obs_len + 1,
                hidden,
                &mut o3,
                &mut h3,
                &mut c3,
            )
            .is_err(),
            "case {case}: wrong obs_len must be rejected"
        );

        let t = 1 + rng.index(12);
        let seq = Sequence {
            obs: fill(t * obs_len, &mut rng),
            actions: (0..t).map(|_| rng.index(na) as i32).collect(),
            rewards: fill(t, &mut rng),
            discounts: fill(t, &mut rng),
            h0: fill(hidden, &mut rng),
            c0: fill(hidden, &mut rng),
            actor_id: rng.index(64),
            valid_len: 1 + rng.index(t),
        };
        frame::encode_sequence(&mut buf, &seq);
        let fr = strip_len(&buf);
        let mut out = Sequence::default();
        frame::decode_sequence(frame::payload(fr), obs_len, hidden, &mut out).unwrap();
        assert_eq!(out, seq, "case {case}");
    }
}

#[test]
fn codec_rejects_truncation_and_corruption() {
    // Property: any single corrupted header byte of interest (magic,
    // kind) and any truncation of header or payload is a hard error.
    let mut rng = Pcg32::seeded(0xBAD);
    let mut buf = Vec::new();
    for _ in 0..100 {
        let rows = 1 + rng.index(8);
        let obs_len = 1 + rng.index(16);
        let hidden = 1 + rng.index(8);
        let obs: Vec<f32> = (0..rows * obs_len).map(|_| rng.next_f32()).collect();
        let h = vec![0.5f32; rows * hidden];
        let c = vec![0.5f32; rows * hidden];
        frame::encode_submit(&mut buf, rng.next_u64(), rows, &obs, &h, &c);
        let fr = strip_len(&buf).to_vec();

        // Truncated header.
        let cut = rng.index(frame::HEADER_LEN);
        assert!(frame::parse_header(&fr[..cut]).is_err());
        // Bad magic.
        let mut bad = fr.clone();
        bad[rng.index(2)] ^= 0x40;
        assert!(frame::parse_header(&bad).is_err());
        // Unknown kind (Ping=7 / Pong=8 are the last valid ones).
        let mut bad = fr.clone();
        bad[2] = 9 + rng.index(200) as u8;
        assert!(frame::parse_header(&bad).is_err());
        // Truncated payload: length disagrees with rows * dims.
        let (mut o2, mut h2, mut c2) = (Vec::new(), Vec::new(), Vec::new());
        let pl = frame::payload(&fr);
        let cut = rng.index(pl.len());
        assert!(frame::decode_submit(
            &pl[..cut],
            rows,
            obs_len,
            hidden,
            &mut o2,
            &mut h2,
            &mut c2
        )
        .is_err());
        // Truncated sequence payloads never panic either.
        let seq = Sequence {
            obs: vec![1.0; 2 * obs_len],
            actions: vec![0; 2],
            rewards: vec![0.0; 2],
            discounts: vec![0.9; 2],
            h0: vec![0.0; hidden],
            c0: vec![0.0; hidden],
            actor_id: 0,
            valid_len: 2,
        };
        frame::encode_sequence(&mut buf, &seq);
        let fr = strip_len(&buf);
        let pl = frame::payload(fr);
        let cut = rng.index(pl.len());
        let mut out = Sequence::default();
        assert!(frame::decode_sequence(&pl[..cut], obs_len, hidden, &mut out).is_err());
    }
}

// ---------------------------------------------------------------------------
// Loopback fleet harness
// ---------------------------------------------------------------------------

fn uds_addr(tag: &str) -> Addr {
    let path = std::env::temp_dir().join(format!(
        "rlarch_fleet_{tag}_{}.sock",
        std::process::id()
    ));
    let _ = std::fs::remove_file(&path);
    Addr::Unix(path)
}

fn wait_for(mut cond: impl FnMut() -> bool, what: &str) {
    let t0 = Instant::now();
    while !cond() {
        assert!(
            t0.elapsed() < Duration::from_secs(10),
            "timed out waiting for {what}"
        );
        std::thread::sleep(Duration::from_millis(10));
    }
}

/// The deterministic fleet workload: 2 actors x 3 env slots on catch,
/// a batch cap below the slot count (multi-row submissions split).
fn fleet_cfg() -> (SystemConfig, ModelDims) {
    let mut cfg = SystemConfig::default();
    cfg.env.name = "catch".into();
    cfg.env.step_cost_us = 0;
    cfg.env.frame_stack = 4;
    cfg.actors.num_actors = 2;
    cfg.actors.envs_per_actor = 3;
    cfg.learner.burn_in = 2;
    cfg.learner.unroll_len = 4;
    cfg.learner.seq_overlap = 2;
    cfg.learner.train_batch = 4;
    cfg.batcher.max_batch = 8;
    cfg.batcher.batch_sizes = vec![1, 8];
    cfg.batcher.timeout_us = 200;
    let dims = ModelDims {
        obs_len: 400,
        hidden: 8,
        num_actions: 4,
        seq_len: 6,
        train_batch: 4,
    };
    (cfg, dims)
}

/// Group a replay snapshot by emitting env slot; per-slot order is
/// emission order, which both paths must preserve.
fn by_slot(seqs: &[Arc<Sequence>]) -> BTreeMap<usize, Vec<Arc<Sequence>>> {
    let mut m: BTreeMap<usize, Vec<Arc<Sequence>>> = BTreeMap::new();
    for s in seqs {
        m.entry(s.actor_id).or_default().push(s.clone());
    }
    m
}

#[test]
fn loopback_uds_fleet_matches_the_in_process_central_path() {
    // Tentpole acceptance: a 1-server + 2-actor loopback fleet run over
    // UDS must produce the same replay stream (per env slot) as the
    // same actors running in-process against the same central batcher.
    let (cfg, dims) = fleet_cfg();
    let rounds = 60u64;

    // --- In-process reference: 2 actor threads, one batcher, local
    // replay (the seed central path).
    let reference = {
        let backend = Backend::Mock(Arc::new(MockModel::new(dims, 11)));
        let metrics = Registry::new();
        let replay = Arc::new(SequenceReplay::new(ReplayConfig {
            capacity: 4_096,
            ..Default::default()
        }));
        let (batcher, handle) =
            Batcher::spawn(cfg.batcher.clone(), backend, metrics.clone());
        let stats: Vec<_> = std::thread::scope(|s| {
            let joins: Vec<_> = (0..cfg.actors.num_actors)
                .map(|id| {
                    let cfg = cfg.clone();
                    let handle = handle.clone();
                    let metrics = metrics.clone();
                    let replay = replay.clone();
                    s.spawn(move || {
                        let policy: Box<dyn PolicyClient> = Box::new(
                            CentralClient::new(handle, id, dims, &metrics),
                        );
                        run_actor(ActorArgs {
                            id,
                            cfg,
                            dims,
                            policy,
                            replay,
                            metrics,
                            shutdown: ShutdownToken::new(),
                            max_rounds: Some(rounds),
                        })
                        .unwrap()
                    })
                })
                .collect();
            joins.into_iter().map(|j| j.join().unwrap()).collect()
        });
        drop(handle);
        batcher.join();
        (stats, replay.snapshot())
    };

    // --- Loopback fleet: same batcher config behind a FleetServer on a
    // UDS socket; the same 2 actors run as remote workers with their
    // own registries and shutdown tokens (process stand-ins).
    let addr = uds_addr("equiv");
    let backend = Backend::Mock(Arc::new(MockModel::new(dims, 11)));
    let server_metrics = Registry::new();
    let server_shutdown = ShutdownToken::new();
    let replay = Arc::new(SequenceReplay::new(ReplayConfig {
        capacity: 4_096,
        ..Default::default()
    }));
    let (batcher, handle) =
        Batcher::spawn(cfg.batcher.clone(), backend, server_metrics.clone());
    let listener = Listener::bind(&addr).unwrap();
    let server = FleetServer::spawn(
        listener,
        handle.clone(),
        replay.clone(),
        FleetServerOpts::default(),
        server_metrics.clone(),
        server_shutdown.clone(),
    );

    let worker_metrics = Registry::new();
    let worker_shutdown = ShutdownToken::new();
    let opts = RemoteClientOpts::default();
    let ingest = Arc::new(
        RemoteIngest::connect(
            &addr,
            dims,
            &opts,
            &worker_metrics,
            worker_shutdown.clone(),
        )
        .unwrap(),
    );
    let fleet_stats: Vec<_> = std::thread::scope(|s| {
        let joins: Vec<_> = (0..cfg.actors.num_actors)
            .map(|id| {
                let cfg = cfg.clone();
                let addr = addr.clone();
                let metrics = worker_metrics.clone();
                let shutdown = worker_shutdown.clone();
                let ingest = ingest.clone();
                s.spawn(move || {
                    let policy: Box<dyn PolicyClient> = Box::new(
                        RemoteClient::connect(
                            &addr,
                            id,
                            dims,
                            opts,
                            &metrics,
                            shutdown.clone(),
                        )
                        .unwrap(),
                    );
                    run_actor(ActorArgs {
                        id,
                        cfg,
                        dims,
                        policy,
                        replay: ingest,
                        metrics,
                        shutdown,
                        max_rounds: Some(rounds),
                    })
                    .unwrap()
                })
            })
            .collect();
        joins.into_iter().map(|j| j.join().unwrap()).collect()
    });
    ingest.goodbye();
    // Everything the workers sent is in flight at most briefly; wait
    // for the ingest connection to land every sequence, then drain.
    let want = reference.1.len() as u64;
    let rx = server_metrics.counter("fleet.rx_sequences");
    wait_for(|| rx.get() >= want, "all sequences to arrive");
    server_shutdown.signal();
    server.join();
    drop(handle);
    batcher.join();

    // Same per-actor stats...
    for (a, b) in reference.0.iter().zip(&fleet_stats) {
        assert_eq!(a.env_steps, b.env_steps);
        assert_eq!(a.episodes, b.episodes);
    }
    // ...and the same replay stream, slot by slot, byte for byte.
    let golden = by_slot(&reference.1);
    let fleet = by_slot(&replay.snapshot());
    assert!(!golden.is_empty(), "reference produced no sequences");
    assert_eq!(
        fleet.keys().collect::<Vec<_>>(),
        golden.keys().collect::<Vec<_>>()
    );
    for (slot, gold) in &golden {
        let got = &fleet[slot];
        assert_eq!(got.len(), gold.len(), "slot {slot} sequence count");
        for (i, (a, b)) in got.iter().zip(gold).enumerate() {
            assert_eq!(a, b, "slot {slot} sequence {i} diverged");
        }
    }

    // The fleet telemetry was live on both sides.
    assert!(worker_metrics.counter("fleet.tx_frames").get() > 0);
    assert!(worker_metrics.counter("fleet.tx_bytes").get() > 0);
    let snap = worker_metrics.snapshot();
    assert!(snap["fleet.rtt_seconds.count"] > 0.0, "client RTT timer");
    assert_eq!(server_metrics.counter("fleet.rx_sequences").get(), want);
    assert!(server_metrics.counter("fleet.accepts").get() >= 3); // 2 infer + 1 ingest
    assert_eq!(server_metrics.counter("fleet.disconnects").get(), 0);
    let ssnap = server_metrics.snapshot();
    assert!(ssnap["fleet.encode_seconds.count"] > 0.0);
    assert!(ssnap["fleet.decode_seconds.count"] > 0.0);
    assert_eq!(ssnap["fleet.connections"], 0.0, "all connections drained");
}

fn policy_dims() -> ModelDims {
    ModelDims {
        obs_len: 8,
        hidden: 4,
        num_actions: 3,
        seq_len: 4,
        train_batch: 2,
    }
}

/// A small deterministic sequence for ingest-path tests.
fn test_seq(d: &ModelDims, slot: usize) -> Sequence {
    let t = 3usize;
    Sequence {
        obs: vec![slot as f32 * 0.125; t * d.obs_len],
        actions: vec![1; t],
        rewards: vec![0.5; t],
        discounts: vec![0.99; t],
        h0: vec![0.0; d.hidden],
        c0: vec![0.0; d.hidden],
        actor_id: slot,
        valid_len: t,
    }
}

/// One manual split-phase round-trip through a remote client.
fn roundtrip(client: &mut RemoteClient, d: &ModelDims, tag: f32) {
    let obs = vec![tag; d.obs_len];
    let h = vec![0.0f32; d.hidden];
    let c = vec![0.0f32; d.hidden];
    client.submit(0, 1, &obs, &h, &c).unwrap();
    let mut q = vec![0.0f32; d.num_actions];
    let (mut h2, mut c2) = (vec![0.0f32; d.hidden], vec![0.0f32; d.hidden]);
    client.wait(0, &mut q, &mut h2, &mut c2).unwrap();
    assert!(q.iter().all(|v| v.is_finite()));
}

struct TestServer {
    server: Option<FleetServer>,
    batcher: Option<Batcher>,
    handle: Option<rlarch::coordinator::BatcherHandle>,
    metrics: Registry,
    shutdown: ShutdownToken,
    addr: Addr,
}

impl TestServer {
    fn start(tag: &str, d: ModelDims, batcher_cfg: BatcherConfig, opts: FleetServerOpts) -> Self {
        let addr = uds_addr(tag);
        let backend = Backend::Mock(Arc::new(MockModel::new(d, 7)));
        let metrics = Registry::new();
        let shutdown = ShutdownToken::new();
        let sink = Arc::new(SequenceReplay::new(ReplayConfig {
            capacity: 64,
            ..Default::default()
        }));
        let (batcher, handle) = Batcher::spawn(batcher_cfg, backend, metrics.clone());
        let listener = Listener::bind(&addr).unwrap();
        let server = FleetServer::spawn(
            listener,
            handle.clone(),
            sink,
            opts,
            metrics.clone(),
            shutdown.clone(),
        );
        TestServer {
            server: Some(server),
            batcher: Some(batcher),
            handle: Some(handle),
            metrics,
            shutdown,
            addr,
        }
    }

    fn stop(mut self) {
        self.shutdown.signal();
        self.server.take().unwrap().join();
        drop(self.handle.take());
        self.batcher.take().unwrap().join();
    }
}

#[test]
fn killed_worker_is_counted_and_survivors_plus_rejoiners_proceed() {
    // Kill-and-reconnect e2e: an uncleanly dying connection is counted
    // as a disconnect (its in-flight replies shed, not stalled), the
    // other connection keeps round-tripping, and a later connect is
    // counted as the reconnect and serves traffic normally.
    let d = policy_dims();
    let srv = TestServer::start(
        "kill",
        d,
        BatcherConfig::default(),
        FleetServerOpts::default(),
    );
    let opts = RemoteClientOpts::default();

    let wm = Registry::new();
    let mut survivor = RemoteClient::connect(
        &srv.addr,
        0,
        d,
        opts,
        &wm,
        ShutdownToken::new(),
    )
    .unwrap();
    roundtrip(&mut survivor, &d, 0.25);

    // The victim: a raw connection that completes the handshake, then
    // dies without a goodbye (a killed worker process).
    {
        let mut stream = dial(&srv.addr, 3, 10, None).unwrap();
        let mut buf = Vec::new();
        frame::encode_hello(
            &mut buf,
            &frame::Hello {
                role: Role::Infer,
                actor_id: 1,
                obs_len: d.obs_len as u32,
                hidden: d.hidden as u32,
                num_actions: d.num_actions as u32,
                seq_len: d.seq_len as u32,
                generation: 0,
                class: 0,
            },
        );
        stream.write_all(&buf).unwrap();
        let conns = srv.metrics.gauge("fleet.connections");
        wait_for(|| conns.get() >= 2.0, "victim connection to register");
        // drop(stream): the unclean death.
    }
    let disconnects = srv.metrics.counter("fleet.disconnects");
    wait_for(|| disconnects.get() >= 1, "the death to be counted");

    // The survivor never noticed.
    roundtrip(&mut survivor, &d, 0.5);

    // The rejoiner: a fresh connect after a recorded death is the
    // kill-and-reconnect signal, and serves traffic like any other.
    let mut rejoiner = RemoteClient::connect(
        &srv.addr,
        1,
        d,
        opts,
        &wm,
        ShutdownToken::new(),
    )
    .unwrap();
    let reconnects = srv.metrics.counter("fleet.reconnects");
    wait_for(|| reconnects.get() >= 1, "the reconnect to be counted");
    roundtrip(&mut rejoiner, &d, 0.75);

    // The ingest link rides out the kill-and-rejoin churn: every
    // sequence pushed through it lands on the server, and the lost-
    // sequence ledger stays at zero.
    let ingest = RemoteIngest::connect(
        &srv.addr,
        d,
        &opts,
        &wm,
        ShutdownToken::new(),
    )
    .unwrap();
    let pushed = 5u64;
    let mut batch: Vec<Sequence> =
        (0..pushed as usize).map(|i| test_seq(&d, i)).collect();
    ingest.add_batch(&mut batch);
    ingest.goodbye();
    let rx = srv.metrics.counter("fleet.rx_sequences");
    wait_for(|| rx.get() >= pushed, "ingest sequences to arrive");
    assert_eq!(rx.get(), pushed, "every pushed sequence arrived exactly once");
    assert_eq!(
        wm.counter("fleet.ingest_lost_sequences").get(),
        0,
        "kill-and-rejoin churn lost no ingest sequences"
    );

    drop(survivor);
    drop(rejoiner);
    srv.stop();
}

#[test]
fn over_budget_submissions_are_shed_and_transparently_retried() {
    // Backpressure acceptance: with a 1-row in-flight budget and slow
    // inference, the second of two back-to-back submissions must be
    // shed (counter, error reply) — and the client's shed-retry loop
    // must still complete both round-trips without error.
    let d = policy_dims();
    let bcfg = BatcherConfig {
        max_batch: 4,
        timeout_us: 200,
        batch_sizes: vec![1, 4],
    };
    let addr = uds_addr("shed");
    let backend = Backend::Mock(Arc::new(
        MockModel::new(d, 7).with_infer_latency(Duration::from_millis(40)),
    ));
    let metrics = Registry::new();
    let shutdown = ShutdownToken::new();
    let sink = Arc::new(SequenceReplay::new(ReplayConfig {
        capacity: 64,
        ..Default::default()
    }));
    let (batcher, handle) = Batcher::spawn(bcfg, backend, metrics.clone());
    let listener = Listener::bind(&addr).unwrap();
    let server = FleetServer::spawn(
        listener,
        handle.clone(),
        sink,
        FleetServerOpts {
            max_inflight_rows: 1,
            insert_batch: 1,
            ..Default::default()
        },
        metrics.clone(),
        shutdown.clone(),
    );

    let wm = Registry::new();
    let mut client = RemoteClient::connect(
        &addr,
        0,
        d,
        RemoteClientOpts::default(),
        &wm,
        ShutdownToken::new(),
    )
    .unwrap();
    let obs = vec![0.5f32; d.obs_len];
    let h = vec![0.0f32; d.hidden];
    let c = vec![0.0f32; d.hidden];
    // Two tickets in flight against a 1-row budget: the second arrives
    // while the first sits under 40ms of inference latency → shed.
    client.submit(0, 1, &obs, &h, &c).unwrap();
    client.submit(1, 1, &obs, &h, &c).unwrap();
    let mut q = vec![0.0f32; d.num_actions];
    let (mut h2, mut c2) = (vec![0.0f32; d.hidden], vec![0.0f32; d.hidden]);
    client.wait(0, &mut q, &mut h2, &mut c2).unwrap();
    client.wait(1, &mut q, &mut h2, &mut c2).unwrap();
    assert!(
        metrics.counter("fleet.shed_rows").get() >= 1,
        "the over-budget submission was shed"
    );
    assert!(
        wm.counter("fleet.resubmits").get() >= 1,
        "the client retried the shed ticket"
    );

    drop(client);
    shutdown.signal();
    server.join();
    drop(handle);
    batcher.join();
}

// ---------------------------------------------------------------------------
// Fault tolerance (DESIGN.md §15)
// ---------------------------------------------------------------------------

/// Dial + manual handshake, returning the write half and a reader that
/// has consumed the server's one reply frame (hello ack or refusal) —
/// callers inspect `reader.frame()`.
fn raw_handshake(
    addr: &Addr,
    d: ModelDims,
    actor_id: u32,
    generation: u32,
) -> (Stream, FrameReader) {
    raw_handshake_class(addr, d, actor_id, generation, 0)
}

/// Like [`raw_handshake`] but declaring a priority class byte — the
/// admission-ladder and breaker tests speak each class raw.
fn raw_handshake_class(
    addr: &Addr,
    d: ModelDims,
    actor_id: u32,
    generation: u32,
    class: u8,
) -> (Stream, FrameReader) {
    let stream = dial(addr, 3, 10, None).unwrap();
    stream
        .set_read_timeout(Some(Duration::from_millis(50)))
        .unwrap();
    let mut writer = stream.try_clone().unwrap();
    let mut reader = FrameReader::new(stream);
    let mut buf = Vec::new();
    frame::encode_hello(
        &mut buf,
        &frame::Hello {
            role: Role::Infer,
            actor_id,
            obs_len: d.obs_len as u32,
            hidden: d.hidden as u32,
            num_actions: d.num_actions as u32,
            seq_len: d.seq_len as u32,
            generation,
            class,
        },
    );
    writer.write_all(&buf).unwrap();
    assert_eq!(reader.read_frame(&|| false).unwrap(), ReadOutcome::Frame);
    (writer, reader)
}

#[test]
fn fault_plan_mutations_never_panic_the_decoder() {
    // FaultPlan-driven corruption fuzz over random frame kinds:
    // whatever the plan's truncate/corrupt stream does to a frame, the
    // defensive decode path (parse, then kind-specific decode) must
    // reject it — never panic, never mis-scatter — and the plan's
    // ledger must count exactly the mutated frames.
    let plan = FaultPlan::from_config(&FaultsConfig {
        seed: 0xC0FFEE,
        truncate_rate: 0.5,
        corrupt_rate: 0.5,
        ..Default::default()
    })
    .expect("armed plan");
    let mut faults = plan.conn(42);
    let mut rng = Pcg32::seeded(0xFA17);
    let mut buf = Vec::new();
    let mut mutated = 0u64;
    for case in 0..300 {
        let rows = 1 + rng.index(4);
        let obs_len = 1 + rng.index(8);
        let hidden = 1 + rng.index(4);
        let na = 1 + rng.index(4);
        match rng.index(4) {
            0 => frame::encode_submit(
                &mut buf,
                rng.next_u64(),
                rows,
                &vec![0.5; rows * obs_len],
                &vec![0.0; rows * hidden],
                &vec![0.0; rows * hidden],
            ),
            1 => frame::encode_reply_ok(
                &mut buf,
                rng.next_u64(),
                0,
                rows,
                &vec![0.5; rows * na],
                &vec![0.0; rows * hidden],
                &vec![0.0; rows * hidden],
            ),
            2 => frame::encode_sequence(
                &mut buf,
                &Sequence {
                    obs: vec![1.0; 2 * obs_len],
                    actions: vec![0; 2],
                    rewards: vec![0.0; 2],
                    discounts: vec![0.9; 2],
                    h0: vec![0.0; hidden],
                    c0: vec![0.0; hidden],
                    actor_id: 0,
                    valid_len: 2,
                },
            ),
            _ => frame::encode_ping(&mut buf, rng.next_u64()),
        }
        let mut fr = strip_len(&buf).to_vec();
        let fault = faults.sample();
        let mutating = matches!(fault, FrameFault::Truncate | FrameFault::Corrupt);
        faults.mutate(&mut fr, fault);
        if !mutating {
            continue;
        }
        mutated += 1;
        let rejected = match frame::parse_header(&fr) {
            Err(_) => true,
            Ok(hd) => match hd.kind {
                FrameKind::Submit => {
                    let (mut o, mut h, mut c) = (Vec::new(), Vec::new(), Vec::new());
                    frame::decode_submit(
                        frame::payload(&fr),
                        hd.rows as usize,
                        obs_len,
                        hidden,
                        &mut o,
                        &mut h,
                        &mut c,
                    )
                    .is_err()
                }
                FrameKind::Sequence => {
                    let mut out = Sequence::default();
                    frame::decode_sequence(frame::payload(&fr), obs_len, hidden, &mut out)
                        .is_err()
                }
                FrameKind::ReplyOk => {
                    let (mut q, mut h, mut c) = (Vec::new(), Vec::new(), Vec::new());
                    frame::decode_reply_ok(
                        frame::payload(&fr),
                        hd.rows as usize,
                        na,
                        hidden,
                        &mut q,
                        &mut h,
                        &mut c,
                    )
                    .is_err()
                }
                // Header-only kinds: a truncation never leaves a whole
                // header behind, so reaching here would mean delivery.
                _ => true,
            },
        };
        assert!(rejected, "case {case}: mutated frame must be rejected");
    }
    assert!(mutated > 0, "the plan never drew a mutating fault");
    let inj = plan.injected();
    assert_eq!(inj.truncated + inj.corrupted, mutated, "ledger reconciles");
}

#[test]
fn plan_mutated_frames_on_the_wire_increment_bad_frames() {
    // The server half of the same property: a plan-mutated frame
    // arriving on a real connection is rejected and counted in
    // `fleet.bad_frames`, the connection is closed, and the server
    // stays healthy for the next one.
    let d = policy_dims();
    let srv = TestServer::start(
        "badframes",
        d,
        BatcherConfig::default(),
        FleetServerOpts::default(),
    );
    let plan = FaultPlan::from_config(&FaultsConfig {
        seed: 3,
        truncate_rate: 1.0,
        ..Default::default()
    })
    .unwrap();
    let bad = srv.metrics.counter("fleet.bad_frames");
    for i in 0..2u64 {
        let (mut writer, _reader) = raw_handshake(&srv.addr, d, 0, 0);
        let mut buf = Vec::new();
        frame::encode_submit(
            &mut buf,
            i,
            1,
            &vec![0.5; d.obs_len],
            &vec![0.0; d.hidden],
            &vec![0.0; d.hidden],
        );
        let mut fr = buf[4..].to_vec();
        let mut faults = plan.conn(7);
        let fault = faults.sample();
        assert_eq!(fault, FrameFault::Truncate, "rate 1.0 always truncates");
        faults.mutate(&mut fr, fault);
        let mut wire = (fr.len() as u32).to_le_bytes().to_vec();
        wire.extend_from_slice(&fr);
        writer.write_all(&wire).unwrap();
        wait_for(|| bad.get() >= i + 1, "the bad frame to be counted");
    }
    // A clean client still round-trips after the garbage.
    let wm = Registry::new();
    let mut client = RemoteClient::connect(
        &srv.addr,
        0,
        d,
        RemoteClientOpts::default(),
        &wm,
        ShutdownToken::new(),
    )
    .unwrap();
    roundtrip(&mut client, &d, 0.5);
    drop(client);
    srv.stop();
}

#[test]
fn silent_connection_is_reaped_and_a_heartbeating_waiter_is_not() {
    // Liveness: a handshaked connection that goes silent past the
    // window is reaped (counted + attributed); a client blocked in a
    // long `wait` survives the same window because its heartbeat pings
    // are proof of life.
    let d = policy_dims();
    let addr = uds_addr("reap");
    let backend = Backend::Mock(Arc::new(
        MockModel::new(d, 7).with_infer_latency(Duration::from_millis(300)),
    ));
    let metrics = Registry::new();
    let shutdown = ShutdownToken::new();
    let sink = Arc::new(SequenceReplay::new(ReplayConfig {
        capacity: 64,
        ..Default::default()
    }));
    let (batcher, handle) =
        Batcher::spawn(BatcherConfig::default(), backend, metrics.clone());
    let listener = Listener::bind(&addr).unwrap();
    let server = FleetServer::spawn(
        listener,
        handle.clone(),
        sink,
        FleetServerOpts {
            liveness_timeout_ms: 120,
            ..Default::default()
        },
        metrics.clone(),
        shutdown.clone(),
    );
    let errors = server.error_slot();

    // The victim handshakes, then never speaks again.
    let (_silent_writer, _silent_reader) = raw_handshake(&addr, d, 1, 0);

    // The waiter: 300ms replies against a 120ms window — only its 40ms
    // heartbeat keeps the connection alive through the wait.
    let wm = Registry::new();
    let mut client = RemoteClient::connect(
        &addr,
        0,
        d,
        RemoteClientOpts {
            heartbeat_ms: 40,
            ..Default::default()
        },
        &wm,
        ShutdownToken::new(),
    )
    .unwrap();
    roundtrip(&mut client, &d, 0.5);
    let reaped = metrics.counter("fleet.reaped");
    wait_for(|| reaped.get() >= 1, "the silent connection to be reaped");
    let msg = errors.lock().unwrap().clone().expect("attributed reap");
    assert!(msg.contains("reaped"), "unexpected first error: {msg}");
    assert_eq!(reaped.get(), 1, "only the silent connection was reaped");
    // The heartbeating client is still on its original connection.
    roundtrip(&mut client, &d, 0.75);
    assert_eq!(wm.counter("fleet.client_reconnects").get(), 0);

    drop(client);
    shutdown.signal();
    server.join();
    drop(handle);
    batcher.join();
}

#[test]
fn ticket_deadline_reconnects_and_resubmits_through_a_mute_server() {
    // Deadline: a server that swallows submissions without replying
    // must trip the client's per-ticket deadline (EWMA floor =
    // liveness_ms), which reconnects, resends the retained frame, and
    // completes against the next (honest) incarnation.
    let d = policy_dims();
    let addr = uds_addr("deadline");
    let listener = Listener::bind(&addr).unwrap();
    let srv = std::thread::spawn(move || {
        // One handler thread per connection: the reconnecting client
        // holds its dead connection open until the new handshake
        // completes, so the accept loop must keep accepting.
        let mut handlers = Vec::new();
        for conn in 1..=2 {
            let stream = loop {
                if let Some(s) = listener.poll_accept().unwrap() {
                    break s;
                }
                std::thread::sleep(Duration::from_millis(2));
            };
            handlers.push(std::thread::spawn(move || {
                let mut buf = Vec::new();
                let mut writer = stream.try_clone().unwrap();
                let mut reader = FrameReader::new(stream);
                assert_eq!(reader.read_frame(&|| false).unwrap(), ReadOutcome::Frame);
                let hello = frame::decode_hello(frame::payload(reader.frame())).unwrap();
                frame::encode_hello(&mut buf, &hello);
                writer.write_all(&buf).unwrap();
                loop {
                    match reader.read_frame(&|| false) {
                        Ok(ReadOutcome::Frame) => {}
                        _ => break, // EOF: the client moved on (or is done)
                    }
                    let hd = frame::parse_header(reader.frame()).unwrap();
                    // Connection 1 is mute; connection 2 answers.
                    if conn == 2 && hd.kind == FrameKind::Submit {
                        frame::encode_reply_ok(
                            &mut buf,
                            hd.ticket,
                            0,
                            1,
                            &vec![0.25; d.num_actions],
                            &vec![0.0; d.hidden],
                            &vec![0.0; d.hidden],
                        );
                        writer.write_all(&buf).unwrap();
                    }
                }
            }));
        }
        for h in handlers {
            h.join().unwrap();
        }
    });

    let wm = Registry::new();
    let mut client = RemoteClient::connect(
        &addr,
        0,
        d,
        RemoteClientOpts {
            liveness_ms: 80,
            ..Default::default()
        },
        &wm,
        ShutdownToken::new(),
    )
    .unwrap();
    roundtrip(&mut client, &d, 0.5);
    assert!(wm.counter("fleet.timeouts").get() >= 1, "deadline tripped");
    assert!(
        wm.counter("fleet.client_reconnects").get() >= 1,
        "deadline recovery reconnected"
    );
    drop(client);
    srv.join().unwrap();
}

#[test]
fn stale_generation_handshake_is_refused_until_resync() {
    // Generation fence: a worker claiming sync to an older incarnation
    // is refused with the `stale generation` marker; a fresh handshake
    // (generation 0, which is how RemoteClient::establish resyncs) is
    // accepted and serves.
    let d = policy_dims();
    let srv = TestServer::start(
        "stalegen",
        d,
        BatcherConfig::default(),
        FleetServerOpts {
            generation: 5,
            ..Default::default()
        },
    );
    let (_writer, reader) = raw_handshake(&srv.addr, d, 0, 3);
    let hd = frame::parse_header(reader.frame()).unwrap();
    assert_eq!(hd.kind, FrameKind::ReplyErr, "stale worker is refused");
    let msg = frame::decode_reply_err(frame::payload(reader.frame())).unwrap();
    assert!(msg.starts_with("stale generation"), "got: {msg}");

    let wm = Registry::new();
    let mut client = RemoteClient::connect(
        &srv.addr,
        0,
        d,
        RemoteClientOpts::default(),
        &wm,
        ShutdownToken::new(),
    )
    .unwrap();
    roundtrip(&mut client, &d, 0.5);
    drop(client);
    srv.stop();
}

#[test]
fn injected_actor_panic_is_supervised_and_restarted_within_budget() {
    // Supervision: the plan's one-shot panic kills an actor thread
    // mid-run; the worker supervisor catches it, counts the restart,
    // reconnects, and the fleet completes with no actor failure.
    let (mut cfg, dims) = fleet_cfg();
    let srv = TestServer::start("panic", dims, cfg.batcher.clone(), FleetServerOpts::default());
    cfg.fleet.connect = srv.addr.to_string();
    cfg.faults.panic_actor = 1;
    cfg.faults.panic_at_step = 4;
    let wm = Registry::new();
    let report =
        run_worker(&cfg, dims, 0, cfg.actors.num_actors, Some(12), wm.clone()).unwrap();
    assert_eq!(report.actor_restarts, 1, "one-shot panic restarts exactly once");
    assert!(
        report.first_error.is_none(),
        "budget covers one panic: {:?}",
        report.first_error
    );
    assert_eq!(report.actors.len(), cfg.actors.num_actors);
    assert!(report.env_steps > 0);
    assert_eq!(wm.counter("fleet.actor_restarts").get(), 1);
    srv.stop();
}

/// One serve + worker incarnation over `addr`; returns the serve
/// report (the worker's is drain-dependent, see `WorkerReport` docs).
fn serve_once(
    cfg: &SystemConfig,
    dims: ModelDims,
    server_metrics: Registry,
) -> rlarch::coordinator::ServeReport {
    let backend = Backend::Mock(Arc::new(MockModel::new(dims, cfg.seed)));
    let scfg = cfg.clone();
    let serve =
        std::thread::spawn(move || run_serve(&scfg, backend, server_metrics).unwrap());
    let wcfg = cfg.clone();
    let worker = std::thread::spawn(move || {
        run_worker(&wcfg, dims, 0, wcfg.actors.num_actors, None, Registry::new()).unwrap()
    });
    let report = serve.join().unwrap();
    worker.join().unwrap();
    report
}

#[test]
fn serve_checkpoints_and_a_restart_resumes_with_a_generation_bump() {
    // Checkpoint/restore: run 1 snapshots periodically and on
    // completion; run 2 (same seed, bigger step budget) adopts the
    // final snapshot — learner steps resume, generation bumps, and a
    // worker synced fresh is accepted by the new incarnation.
    let (mut cfg, dims) = fleet_cfg();
    let addr = uds_addr("ckpt");
    cfg.fleet.listen = addr.to_string();
    cfg.fleet.connect = addr.to_string();
    cfg.learner.min_replay = 8;
    cfg.learner.max_steps = 12;
    let ckdir = std::env::temp_dir().join(format!("rlarch_ckpt_{}", std::process::id()));
    let _ = std::fs::remove_dir_all(&ckdir);
    cfg.fleet.checkpoint_dir = ckdir.to_string_lossy().into_owned();
    cfg.fleet.checkpoint_every = 5;

    let r1 = serve_once(&cfg, dims, Registry::new());
    assert_eq!(r1.generation, 1, "first checkpointed incarnation");
    assert_eq!(r1.resumed_steps, 0);
    assert!(r1.checkpoints >= 1, "periodic + final snapshots");
    assert_eq!(r1.learner.steps, 12);
    assert!(ckdir.join("state.kv").exists(), "state snapshot on disk");
    assert!(ckdir.join("params.bin").exists(), "params snapshot on disk");

    cfg.learner.max_steps = 20;
    let r2 = serve_once(&cfg, dims, Registry::new());
    assert_eq!(r2.generation, 2, "each incarnation bumps the generation");
    assert_eq!(r2.resumed_steps, 12, "resumed at run 1's final step");
    assert_eq!(r2.learner.steps, 20, "trained only the remaining steps");
    let _ = std::fs::remove_dir_all(&ckdir);
}

#[test]
fn chaos_soak_completes_with_every_fault_accounted() {
    // The headline: a loopback fleet under a seeded plan of drops,
    // delays, corruption, truncation, kills, inference stalls, and an
    // actor panic still completes training — zero hung tickets — and
    // the `fleet.*` metrics reconcile against the plan's own ledger.
    let (mut cfg, dims) = fleet_cfg();
    let addr = uds_addr("chaos");
    cfg.fleet.listen = addr.to_string();
    cfg.fleet.connect = addr.to_string();
    cfg.learner.min_replay = 8;
    cfg.learner.max_steps = 25;
    cfg.fleet.heartbeat_interval_ms = 40;
    cfg.fleet.liveness_timeout_ms = 150;
    cfg.faults = FaultsConfig {
        seed: 2020,
        drop_rate: 0.01,
        delay_rate: 0.05,
        delay_ms: 2,
        truncate_rate: 0.01,
        corrupt_rate: 0.01,
        kill_rate: 0.005,
        stall_rate: 0.05,
        stall_ms: 5,
        panic_actor: 0,
        panic_at_step: 3,
    };

    let sm = Registry::new();
    let backend = Backend::Mock(Arc::new(MockModel::new(dims, cfg.seed)));
    let scfg = cfg.clone();
    let sm2 = sm.clone();
    let serve = std::thread::spawn(move || run_serve(&scfg, backend, sm2).unwrap());
    let wm = Registry::new();
    let wcfg = cfg.clone();
    let wm2 = wm.clone();
    let worker = std::thread::spawn(move || {
        run_worker(&wcfg, dims, 0, wcfg.actors.num_actors, None, wm2).unwrap()
    });
    let report = serve.join().unwrap();
    let wreport = worker.join().unwrap();

    assert_eq!(report.learner.steps, 25, "the learner completed under chaos");
    let inj = report.injected.expect("armed plan records a ledger");
    assert!(
        inj.killed
            + inj.dropped
            + inj.delayed
            + inj.truncated
            + inj.corrupted
            + inj.stalled
            > 0,
        "the plan actually fired: {inj:?}"
    );
    // Every mutated frame was rejected by the decoder and counted —
    // nothing corrupt was ever delivered.
    assert_eq!(
        sm.counter("fleet.bad_frames").get(),
        inj.truncated + inj.corrupted,
        "bad_frames reconciles against the ledger: {inj:?}"
    );
    // Every injected kill closed a connection the server noticed.
    assert!(
        sm.counter("fleet.disconnects").get() >= inj.killed,
        "kills surface as disconnects: {inj:?}"
    );
    // The one-shot actor panic restarted exactly once, within budget.
    assert_eq!(wreport.actor_restarts, 1);
    assert_eq!(wm.counter("fleet.actor_restarts").get(), 1);
}

// ---------------------------------------------------------------------------
// Resilient serving (DESIGN.md §16)
// ---------------------------------------------------------------------------

/// Block until a whole frame lands on a raw connection and return its
/// parsed header (the bytes stay in `reader.frame()`).
fn read_raw_frame(reader: &mut FrameReader) -> frame::FrameHeader {
    loop {
        match reader.read_frame(&|| false).unwrap() {
            ReadOutcome::Frame => {
                return frame::parse_header(reader.frame()).unwrap()
            }
            ReadOutcome::TimedOut => continue,
            o => panic!("raw connection died mid-reply: {o:?}"),
        }
    }
}

/// Submit `rows` constant rows on a raw connection.
fn raw_submit(writer: &mut Stream, d: &ModelDims, ticket: u64, rows: usize) {
    let mut buf = Vec::new();
    frame::encode_submit(
        &mut buf,
        ticket,
        rows,
        &vec![0.5; rows * d.obs_len],
        &vec![0.0; rows * d.hidden],
        &vec![0.0; rows * d.hidden],
    );
    writer.write_all(&buf).unwrap();
}

/// Read replies for one submission until all `rows` land (the batcher
/// may chunk them) or an error reply arrives, which is returned.
fn read_submit_outcome(
    reader: &mut FrameReader,
    rows: u64,
) -> Result<(), String> {
    let mut done = 0u64;
    while done < rows {
        let hd = read_raw_frame(reader);
        match hd.kind {
            FrameKind::ReplyOk => done += hd.rows as u64,
            FrameKind::ReplyErr => {
                return Err(
                    frame::decode_reply_err(frame::payload(reader.frame()))
                        .unwrap()
                        .to_string(),
                )
            }
            k => panic!("unexpected {k:?} on infer connection"),
        }
    }
    Ok(())
}

/// Pull `key=value` out of a control-socket reply line.
fn stat_u64(reply: &str, key: &str) -> u64 {
    let pat = format!("{key}=");
    reply
        .split_whitespace()
        .find_map(|kv| kv.strip_prefix(pat.as_str()))
        .unwrap_or_else(|| panic!("missing {key} in `{reply}`"))
        .parse()
        .unwrap()
}

/// One reloadable fleet run at the FleetServer level: 2 remote actors,
/// an armed (but policy-free) serving gate, and `swaps` hot swaps
/// performed mid-run through the same public surface `do_reload` uses —
/// pause admission, drain in-flight rows, bump the generation fence,
/// sever every infer connection, resume. Returns per-actor stats, the
/// replay snapshot, both registries, and the final generation.
fn reloadable_fleet_run(
    tag: &str,
    swaps: u32,
) -> (Vec<ActorStats>, Vec<Arc<Sequence>>, Registry, Registry, u32) {
    let (cfg, dims) = fleet_cfg();
    let rounds = 60u64;
    let addr = uds_addr(tag);
    // A little inference latency stretches the run so both swaps land
    // mid-traffic; it cannot change any computed byte.
    let backend = Backend::Mock(Arc::new(
        MockModel::new(dims, 11).with_infer_latency(Duration::from_millis(2)),
    ));
    let sm = Registry::new();
    let server_shutdown = ShutdownToken::new();
    let replay = Arc::new(SequenceReplay::new(ReplayConfig {
        capacity: 4_096,
        ..Default::default()
    }));
    let (batcher, handle) =
        Batcher::spawn(cfg.batcher.clone(), backend, sm.clone());
    let listener = Listener::bind(&addr).unwrap();
    let gate = Arc::new(ServeGate::new(None, None));
    let server = FleetServer::spawn(
        listener,
        handle.clone(),
        replay.clone(),
        FleetServerOpts {
            gate: Some(gate.clone()),
            ..Default::default()
        },
        sm.clone(),
        server_shutdown.clone(),
    );
    let gen_cell = server.generation_cell();
    let registry = server.conn_registry();

    let wm = Registry::new();
    let worker_shutdown = ShutdownToken::new();
    let opts = RemoteClientOpts::default();
    let ingest = Arc::new(
        RemoteIngest::connect(&addr, dims, &opts, &wm, worker_shutdown.clone())
            .unwrap(),
    );
    let stats: Vec<ActorStats> = std::thread::scope(|s| {
        let joins: Vec<_> = (0..cfg.actors.num_actors)
            .map(|id| {
                let cfg = cfg.clone();
                let addr = addr.clone();
                let metrics = wm.clone();
                let shutdown = worker_shutdown.clone();
                let ingest = ingest.clone();
                s.spawn(move || {
                    let policy: Box<dyn PolicyClient> = Box::new(
                        RemoteClient::connect(
                            &addr,
                            id,
                            dims,
                            opts,
                            &metrics,
                            shutdown.clone(),
                        )
                        .unwrap(),
                    );
                    run_actor(ActorArgs {
                        id,
                        cfg,
                        dims,
                        policy,
                        replay: ingest,
                        metrics,
                        shutdown,
                        max_rounds: Some(rounds),
                    })
                    .unwrap()
                })
            })
            .collect();
        // The swap driver: wait for live traffic, then swap — exactly
        // the drain → fence-bump → sever → resume sequence of
        // `do_reload`, minus the checkpoint I/O.
        let rx = sm.counter("fleet.rx_sequences");
        for i in 0..swaps {
            let threshold = 15 * (i as u64 + 1);
            wait_for(|| rx.get() >= threshold, "traffic before the swap");
            gate.set_admitting(false);
            wait_for(|| gate.inflight_rows() == 0, "in-flight rows to drain");
            let g = gen_cell.load(Ordering::Acquire);
            gen_cell.store(g + 1, Ordering::Release);
            registry.sever_all();
            gate.set_admitting(true);
        }
        joins.into_iter().map(|j| j.join().unwrap()).collect()
    });
    ingest.goodbye();
    // Connections at zero ⇒ the ingest reader flushed its tail batch.
    let conns = sm.gauge("fleet.connections");
    wait_for(|| conns.get() == 0.0, "connections to drain");
    server_shutdown.signal();
    server.join();
    drop(handle);
    batcher.join();
    let generation = gen_cell.load(Ordering::Acquire);
    (stats, replay.snapshot(), sm, wm, generation)
}

#[test]
fn hot_reload_swap_preserves_the_replay_stream() {
    // Tentpole acceptance: a run that hot-swaps twice under traffic
    // must produce the *same* per-slot replay stream, byte for byte, as
    // an unswapped run — actors pause on shed, reconnect through the
    // sever, resync the generation, and resubmit; nothing is lost and
    // nothing is computed differently.
    let (base_stats, base_seqs, _, _, base_gen) =
        reloadable_fleet_run("swap0", 0);
    let (stats, seqs, sm, wm, generation) = reloadable_fleet_run("swap2", 2);

    assert_eq!(base_gen, 0, "no swap, no bump");
    assert_eq!(generation, 2, "each swap bumps the generation fence");
    for (a, b) in base_stats.iter().zip(&stats) {
        assert_eq!(a.env_steps, b.env_steps);
        assert_eq!(a.episodes, b.episodes);
    }
    let golden = by_slot(&base_seqs);
    let swapped = by_slot(&seqs);
    assert!(!golden.is_empty(), "reference produced no sequences");
    assert_eq!(
        swapped.keys().collect::<Vec<_>>(),
        golden.keys().collect::<Vec<_>>()
    );
    for (slot, gold) in &golden {
        let got = &swapped[slot];
        assert_eq!(got.len(), gold.len(), "slot {slot} sequence count");
        for (i, (a, b)) in got.iter().zip(gold).enumerate() {
            assert_eq!(a, b, "slot {slot} sequence {i} diverged");
        }
    }
    // The swaps actually severed and the fleet actually recovered.
    assert!(
        sm.counter("fleet.reconnects").get() >= 1,
        "severed clients came back"
    );
    assert!(
        wm.counter("fleet.client_reconnects").get() >= 1,
        "clients rode out the sever"
    );
    // Actor-class traffic is never admission-shed — the pause sheds
    // are flow control and every one was resubmitted.
    assert_eq!(sm.counter("serve.admission_sheds_actor").get(), 0);
    assert_eq!(
        wm.counter("fleet.ingest_lost_sequences").get(),
        0,
        "hot swaps lost no experience"
    );
}

#[test]
fn admission_ladder_sheds_bulk_then_eval_and_never_actor() {
    // Overload ladder e2e over raw wire classes: bulk fills the window
    // to the limit and is shed first; eval is still admitted until the
    // severe (1.5x) threshold, then shed; actor traffic is admitted at
    // every level. Reading each reply before the next submit serializes
    // the admission decisions.
    let d = policy_dims();
    let policy = AdmissionPolicy::new(
        Duration::from_millis(80_000), // whole test inside one window
        64,
        0,
        Duration::ZERO,
        Instant::now(),
    );
    let gate = Arc::new(ServeGate::new(Some(policy), None));
    let srv = TestServer::start(
        "ladder",
        d,
        BatcherConfig::default(),
        FleetServerOpts {
            gate: Some(gate),
            ..Default::default()
        },
    );
    let (mut actor_w, mut actor_r) =
        raw_handshake_class(&srv.addr, d, 0, 0, 0);
    let (mut eval_w, mut eval_r) = raw_handshake_class(&srv.addr, d, 1, 0, 1);
    let (mut bulk_w, mut bulk_r) = raw_handshake_class(&srv.addr, d, 2, 0, 2);

    // Bulk admits up to the 64-row window limit...
    for t in 0..8u64 {
        raw_submit(&mut bulk_w, &d, t, 8);
        read_submit_outcome(&mut bulk_r, 8)
            .expect("bulk under the limit admits");
    }
    // ...then sheds first.
    raw_submit(&mut bulk_w, &d, 8, 8);
    let err = read_submit_outcome(&mut bulk_r, 8).unwrap_err();
    assert!(
        err.starts_with("shed: overload: bulk traffic shed"),
        "got: {err}"
    );
    // Eval admits through ShedBulk (window climbs 64 → 96)...
    for t in 0..4u64 {
        raw_submit(&mut eval_w, &d, t, 8);
        read_submit_outcome(&mut eval_r, 8)
            .expect("eval admits through the bulk shed level");
    }
    // ...until the severe level turns everyone but actors away.
    raw_submit(&mut eval_w, &d, 4, 8);
    let err = read_submit_outcome(&mut eval_r, 8).unwrap_err();
    assert!(
        err.starts_with("shed: overload: only actor traffic admitted"),
        "got: {err}"
    );
    // Actor-class traffic is admitted at the worst overload level.
    raw_submit(&mut actor_w, &d, 0, 8);
    read_submit_outcome(&mut actor_r, 8).expect("actor class is never shed");

    assert_eq!(srv.metrics.counter("serve.admission_sheds_bulk").get(), 1);
    assert_eq!(srv.metrics.counter("serve.admission_sheds_eval").get(), 1);
    assert_eq!(srv.metrics.counter("serve.admission_sheds_actor").get(), 0);
    drop((actor_w, eval_w, bulk_w));
    srv.stop();
}

#[test]
fn circuit_breaker_trips_fails_fast_and_probes_half_open() {
    // Breaker e2e against a backend that always fails: consecutive
    // backend errors trip the breaker (fail-fast `shed:` replies), the
    // cooloff admits exactly one half-open probe, and the probe's
    // failure re-opens the circuit. The writer feeds the breaker after
    // writing each reply, so the trip point is raced by design — loop
    // until the fail-fast reply appears instead of asserting it.
    let d = policy_dims();
    let addr = uds_addr("breaker");
    let backend = Backend::Mock(Arc::new(
        MockModel::new(d, 7).with_infer_error("injected backend fault"),
    ));
    let metrics = Registry::new();
    let shutdown = ShutdownToken::new();
    let sink = Arc::new(SequenceReplay::new(ReplayConfig {
        capacity: 64,
        ..Default::default()
    }));
    let (batcher, handle) =
        Batcher::spawn(BatcherConfig::default(), backend, metrics.clone());
    let gate = Arc::new(ServeGate::new(
        None,
        Some(CircuitBreaker::new(2, Duration::from_millis(50), Instant::now())),
    ));
    let listener = Listener::bind(&addr).unwrap();
    let server = FleetServer::spawn(
        listener,
        handle.clone(),
        sink,
        FleetServerOpts {
            gate: Some(gate),
            ..Default::default()
        },
        metrics.clone(),
        shutdown.clone(),
    );

    let (mut w, mut r) = raw_handshake_class(&addr, d, 0, 0, 0);
    let mut ticket = 0u64;
    let mut backend_errors = 0u64;
    loop {
        assert!(ticket < 100, "breaker never tripped");
        raw_submit(&mut w, &d, ticket, 1);
        ticket += 1;
        let err = read_submit_outcome(&mut r, 1)
            .expect_err("backend always fails");
        if err.starts_with("shed: circuit open: backend failing") {
            break;
        }
        backend_errors += 1;
    }
    assert!(
        backend_errors >= 2,
        "the threshold's worth of real failures reached the client"
    );
    assert!(metrics.counter("serve.breaker_sheds").get() >= 1);

    // Past the cooloff the next submission is the half-open probe: it
    // reaches the (dead) backend and comes back a real error, not a
    // shed — then its failure re-opens the circuit.
    std::thread::sleep(Duration::from_millis(80));
    raw_submit(&mut w, &d, ticket, 1);
    ticket += 1;
    let probe =
        read_submit_outcome(&mut r, 1).expect_err("probe hits a dead backend");
    assert!(
        !probe.starts_with("shed:"),
        "half-open admits exactly one probe: {probe}"
    );
    loop {
        assert!(ticket < 200, "breaker never re-opened");
        raw_submit(&mut w, &d, ticket, 1);
        ticket += 1;
        let err = read_submit_outcome(&mut r, 1).unwrap_err();
        if err.starts_with("shed: circuit open: backend failing") {
            break;
        }
    }
    assert!(metrics.counter("serve.breaker_sheds").get() >= 2);

    drop(w);
    shutdown.signal();
    server.join();
    drop(handle);
    batcher.join();
}

#[test]
fn dead_ingest_link_attributes_every_lost_sequence() {
    // The loss ledger: a live link lands every sequence; once the
    // server is gone and the single reconnect fails, the link declares
    // itself dead and every sequence handed to it afterwards is counted
    // in `fleet.ingest_lost_sequences`, one for one.
    let d = policy_dims();
    let srv = TestServer::start(
        "ingestlost",
        d,
        BatcherConfig::default(),
        FleetServerOpts::default(),
    );
    let wm = Registry::new();
    let shutdown = ShutdownToken::new();
    let opts = RemoteClientOpts {
        connect_retries: 0,
        backoff_ms: 1,
        ..Default::default()
    };
    let ingest =
        RemoteIngest::connect(&srv.addr, d, &opts, &wm, shutdown.clone())
            .unwrap();
    let mut batch = vec![test_seq(&d, 0), test_seq(&d, 1)];
    ingest.add_batch(&mut batch);
    let rx = srv.metrics.counter("fleet.rx_sequences");
    wait_for(|| rx.get() >= 2, "the live link to land sequences");
    srv.stop();

    // Pushes against the dead server fail, the reconnect fails, the
    // link gives up and signals worker shutdown.
    let lost = wm.counter("fleet.ingest_lost_sequences");
    let mut i = 2usize;
    while !shutdown.is_signalled() {
        assert!(i < 1_000, "the dead link never declared itself");
        let mut one = vec![test_seq(&d, i)];
        ingest.add_batch(&mut one);
        i += 1;
    }
    assert!(lost.get() >= 1, "the dying push was attributed");
    assert!(wm.counter("fleet.ingest_errors").get() >= 1);
    // From now on the attribution is exact: every sequence is lost.
    let base = lost.get();
    let mut three = vec![test_seq(&d, 0), test_seq(&d, 1), test_seq(&d, 2)];
    ingest.add_batch(&mut three);
    assert_eq!(lost.get(), base + 3, "one counted loss per sequence");
}

#[test]
fn control_socket_drives_reload_and_graceful_shutdown_under_traffic() {
    // Lifecycle e2e through the real `rlarch serve --control` path: two
    // workers train against a checkpointing server while a control
    // client walks health → ready → reload → stats → shutdown. The
    // reload bumps the generation under traffic; the shutdown drains,
    // checkpoints, and sends every worker a goodbye.
    let (mut cfg, dims) = fleet_cfg();
    let addr = uds_addr("ctl_data");
    let ctl = uds_addr("ctl_ctl");
    cfg.fleet.listen = addr.to_string();
    cfg.fleet.connect = addr.to_string();
    cfg.learner.min_replay = 8;
    cfg.learner.max_steps = 1_000_000; // the control socket ends the run
    let ckdir = std::env::temp_dir()
        .join(format!("rlarch_reload_{}", std::process::id()));
    let _ = std::fs::remove_dir_all(&ckdir);
    cfg.fleet.checkpoint_dir = ckdir.to_string_lossy().into_owned();
    cfg.fleet.checkpoint_every = 2;
    cfg.serve.control = ctl.to_string();

    let sm = Registry::new();
    let backend = Backend::Mock(Arc::new(MockModel::new(dims, cfg.seed)));
    let scfg = cfg.clone();
    let sm2 = sm.clone();
    let serve =
        std::thread::spawn(move || run_serve(&scfg, backend, sm2).unwrap());
    let workers: Vec<_> = (0..2usize)
        .map(|w| {
            let wcfg = cfg.clone();
            let wm = Registry::new();
            std::thread::spawn(move || {
                let report =
                    run_worker(&wcfg, dims, w, 1, None, wm.clone()).unwrap();
                (report, wm)
            })
        })
        .collect();

    wait_for(
        || {
            send_command(&ctl, "health")
                .map(|r| r == "ok healthy")
                .unwrap_or(false)
        },
        "the control socket to come up",
    );
    let ready = send_command(&ctl, "ready").unwrap();
    assert!(ready.starts_with("ok ready generation="), "got: {ready}");
    wait_for(
        || {
            send_command(&ctl, "stats")
                .map(|s| stat_u64(&s, "checkpoints") >= 1)
                .unwrap_or(false)
        },
        "a checkpoint to land on disk",
    );
    let before = stat_u64(&send_command(&ctl, "stats").unwrap(), "sequences");
    let reload =
        send_command(&ctl, &format!("reload {}", cfg.fleet.checkpoint_dir))
            .unwrap();
    assert!(reload.starts_with("ok reloaded"), "got: {reload}");
    assert!(reload.contains("generation 2"), "got: {reload}");
    wait_for(
        || {
            send_command(&ctl, "stats")
                .map(|s| stat_u64(&s, "sequences") > before)
                .unwrap_or(false)
        },
        "serving to resume after the reload",
    );
    let stats = send_command(&ctl, "stats").unwrap();
    assert_eq!(stat_u64(&stats, "reloads"), 1);
    assert_eq!(stat_u64(&stats, "generation"), 2);
    assert_eq!(stat_u64(&stats, "sheds_actor"), 0);
    let bye = send_command(&ctl, "shutdown").unwrap();
    assert!(bye.starts_with("ok shutting down"), "got: {bye}");

    let report = serve.join().unwrap();
    let wreports: Vec<_> =
        workers.into_iter().map(|w| w.join().unwrap()).collect();
    assert_eq!(report.reloads, 1);
    assert_eq!(report.generation, 2);
    assert!(report.checkpoints >= 1);
    assert!(report.sequences > 0, "traffic flowed across the reload");
    assert_eq!(sm.counter("serve.admission_sheds_actor").get(), 0);
    // The reload severed the live infer connections; the workers rode
    // it out by reconnecting (env progress at drain is worker-timing
    // dependent, so only the reconnect is asserted).
    let reconnects: u64 = wreports
        .iter()
        .map(|(_, wm)| wm.counter("fleet.client_reconnects").get())
        .sum();
    assert!(reconnects >= 1, "workers reconnected through the sever");
    let _ = std::fs::remove_dir_all(&ckdir);
}

#[test]
fn chaos_soak_with_hot_reloads_still_reconciles() {
    // The PR 9 chaos soak rerun with two hot-reloads injected mid-soak:
    // training still hits the exact step target, both reloads are
    // drain-attributed, and the fault ledger still reconciles.
    let (mut cfg, dims) = fleet_cfg();
    let addr = uds_addr("chaos_reload");
    let ctl = uds_addr("chaos_reload_ctl");
    cfg.fleet.listen = addr.to_string();
    cfg.fleet.connect = addr.to_string();
    cfg.learner.min_replay = 8;
    cfg.learner.max_steps = 40;
    cfg.fleet.heartbeat_interval_ms = 40;
    cfg.fleet.liveness_timeout_ms = 150;
    let ckdir = std::env::temp_dir()
        .join(format!("rlarch_chaos_reload_{}", std::process::id()));
    let _ = std::fs::remove_dir_all(&ckdir);
    cfg.fleet.checkpoint_dir = ckdir.to_string_lossy().into_owned();
    cfg.fleet.checkpoint_every = 2;
    cfg.serve.control = ctl.to_string();
    cfg.faults = FaultsConfig {
        seed: 2020,
        drop_rate: 0.01,
        delay_rate: 0.05,
        delay_ms: 2,
        truncate_rate: 0.01,
        corrupt_rate: 0.01,
        kill_rate: 0.005,
        stall_rate: 0.05,
        stall_ms: 5,
        panic_actor: 0,
        panic_at_step: 3,
    };

    let sm = Registry::new();
    // 20ms per train step paces the learner (40 steps ≥ 800ms of soak)
    // so both reloads land mid-run, deterministically before step 40.
    let backend = Backend::Mock(Arc::new(
        MockModel::new(dims, cfg.seed)
            .with_train_latency(Duration::from_millis(20)),
    ));
    let scfg = cfg.clone();
    let sm2 = sm.clone();
    let serve =
        std::thread::spawn(move || run_serve(&scfg, backend, sm2).unwrap());
    let wm = Registry::new();
    let wcfg = cfg.clone();
    let wm2 = wm.clone();
    let worker = std::thread::spawn(move || {
        run_worker(&wcfg, dims, 0, wcfg.actors.num_actors, None, wm2).unwrap()
    });

    wait_for(
        || {
            send_command(&ctl, "stats")
                .map(|s| stat_u64(&s, "checkpoints") >= 1)
                .unwrap_or(false)
        },
        "the first checkpoint under chaos",
    );
    let dir = cfg.fleet.checkpoint_dir.clone();
    let r1 = send_command(&ctl, &format!("reload {dir}")).unwrap();
    assert!(r1.contains("generation 2"), "got: {r1}");
    let mid = stat_u64(&send_command(&ctl, "stats").unwrap(), "sequences");
    wait_for(
        || {
            send_command(&ctl, "stats")
                .map(|s| stat_u64(&s, "sequences") > mid)
                .unwrap_or(false)
        },
        "serving to resume between reloads",
    );
    let r2 = send_command(&ctl, &format!("reload {dir}")).unwrap();
    assert!(r2.contains("generation 3"), "got: {r2}");

    let report = serve.join().unwrap();
    let wreport = worker.join().unwrap();
    assert_eq!(
        report.learner.steps, 40,
        "chaos plus reloads still hits the step target"
    );
    assert_eq!(report.reloads, 2);
    assert_eq!(report.generation, 3);
    let inj = report.injected.expect("armed plan records a ledger");
    // Severs close sockets cleanly mid-frame at worst — they surface
    // as disconnects, never as decoder-rejected frames, so the PR 9
    // reconciliations hold unchanged.
    assert_eq!(
        sm.counter("fleet.bad_frames").get(),
        inj.truncated + inj.corrupted,
        "bad_frames reconciles against the ledger: {inj:?}"
    );
    assert!(
        sm.counter("fleet.disconnects").get() >= inj.killed,
        "kills (and severs) surface as disconnects: {inj:?}"
    );
    assert_eq!(wreport.actor_restarts, 1, "the one-shot panic restarted");
    // Both drains settled inside the bound and were attributed.
    let snap = sm.snapshot();
    assert!(snap.contains_key("serve.drain_ms"), "drain time attributed");
    assert_eq!(sm.counter("serve.drain_timeouts").get(), 0);
    assert_eq!(sm.counter("serve.admission_sheds_actor").get(), 0);
    let _ = std::fs::remove_dir_all(&ckdir);
}
