//! Fleet transport integration (DESIGN.md §14): wire-codec property
//! tests against random shapes and corrupted streams, loopback
//! UDS fleet equivalence against the in-process central path,
//! backpressure shedding, and the kill-and-reconnect lifecycle.
//!
//! The "processes" here are threads with separate metric registries and
//! shutdown tokens talking over a real Unix-domain socket — the same
//! frames, same handshake, same drain protocol as `rlarch serve` /
//! `rlarch actor --connect`, minus the fork.

use rlarch::config::{BatcherConfig, SystemConfig};
use rlarch::coordinator::actor::{run_actor, ActorArgs};
use rlarch::coordinator::Batcher;
use rlarch::exec::ShutdownToken;
use rlarch::metrics::Registry;
use rlarch::policy::{CentralClient, PolicyClient};
use rlarch::replay::{ReplayConfig, SequenceReplay};
use rlarch::rl::Sequence;
use rlarch::runtime::{Backend, MockModel, ModelDims};
use rlarch::transport::frame::{self, FrameKind, Role};
use rlarch::transport::{
    dial, Addr, FleetServer, FleetServerOpts, Listener, RemoteClient, RemoteClientOpts,
    RemoteIngest,
};
use rlarch::util::prng::Pcg32;
use std::collections::BTreeMap;
use std::io::Write;
use std::sync::Arc;
use std::time::{Duration, Instant};

// ---------------------------------------------------------------------------
// Codec property tests
// ---------------------------------------------------------------------------

fn strip_len(buf: &[u8]) -> &[u8] {
    let len = u32::from_le_bytes(buf[0..4].try_into().unwrap()) as usize;
    assert_eq!(len, buf.len() - 4, "length prefix covers the frame");
    &buf[4..]
}

#[test]
fn codec_roundtrips_random_rows_dims_and_tickets() {
    // Property: for random (rows, obs_len, hidden, num_actions, ticket,
    // slot0), encode → parse → decode is the identity on every field.
    let mut rng = Pcg32::seeded(0xF1EE7);
    let mut buf = Vec::new();
    for case in 0..200 {
        let rows = 1 + rng.index(32);
        let obs_len = 1 + rng.index(64);
        let hidden = 1 + rng.index(32);
        let na = 1 + rng.index(8);
        let ticket = rng.next_u64();
        let slot0 = rng.next_u32() >> 8;
        let fill = |n: usize, rng: &mut Pcg32| -> Vec<f32> {
            (0..n).map(|_| rng.next_f32() * 2.0 - 1.0).collect()
        };

        let obs = fill(rows * obs_len, &mut rng);
        let h = fill(rows * hidden, &mut rng);
        let c = fill(rows * hidden, &mut rng);
        frame::encode_submit(&mut buf, ticket, rows, &obs, &h, &c);
        let fr = strip_len(&buf);
        let hd = frame::parse_header(fr).unwrap();
        assert_eq!(
            (hd.kind, hd.ticket, hd.rows),
            (FrameKind::Submit, ticket, rows as u32),
            "case {case}"
        );
        let (mut o2, mut h2, mut c2) = (Vec::new(), Vec::new(), Vec::new());
        frame::decode_submit(
            frame::payload(fr),
            rows,
            obs_len,
            hidden,
            &mut o2,
            &mut h2,
            &mut c2,
        )
        .unwrap();
        assert_eq!((o2, h2, c2), (obs, h, c), "case {case}");

        let q = fill(rows * na, &mut rng);
        let qh = fill(rows * hidden, &mut rng);
        let qc = fill(rows * hidden, &mut rng);
        frame::encode_reply_ok(&mut buf, ticket, slot0, rows, &q, &qh, &qc);
        let fr = strip_len(&buf);
        let hd = frame::parse_header(fr).unwrap();
        assert_eq!((hd.ticket, hd.slot0, hd.rows), (ticket, slot0, rows as u32));
        let (mut q2, mut h2, mut c2) = (Vec::new(), Vec::new(), Vec::new());
        frame::decode_reply_ok(
            frame::payload(fr),
            rows,
            na,
            hidden,
            &mut q2,
            &mut h2,
            &mut c2,
        )
        .unwrap();
        assert_eq!((q2, h2, c2), (q, qh, qc), "case {case}");

        // A decode against the WRONG dims must fail, never mis-scatter
        // (payload length disagrees with rows * dims).
        let (mut o3, mut h3, mut c3) = (Vec::new(), Vec::new(), Vec::new());
        frame::encode_submit(&mut buf, ticket, rows, &obs, &h, &c);
        let fr = strip_len(&buf);
        assert!(
            frame::decode_submit(
                frame::payload(fr),
                rows,
                obs_len + 1,
                hidden,
                &mut o3,
                &mut h3,
                &mut c3,
            )
            .is_err(),
            "case {case}: wrong obs_len must be rejected"
        );

        let t = 1 + rng.index(12);
        let seq = Sequence {
            obs: fill(t * obs_len, &mut rng),
            actions: (0..t).map(|_| rng.index(na) as i32).collect(),
            rewards: fill(t, &mut rng),
            discounts: fill(t, &mut rng),
            h0: fill(hidden, &mut rng),
            c0: fill(hidden, &mut rng),
            actor_id: rng.index(64),
            valid_len: 1 + rng.index(t),
        };
        frame::encode_sequence(&mut buf, &seq);
        let fr = strip_len(&buf);
        let mut out = Sequence::default();
        frame::decode_sequence(frame::payload(fr), obs_len, hidden, &mut out).unwrap();
        assert_eq!(out, seq, "case {case}");
    }
}

#[test]
fn codec_rejects_truncation_and_corruption() {
    // Property: any single corrupted header byte of interest (magic,
    // kind) and any truncation of header or payload is a hard error.
    let mut rng = Pcg32::seeded(0xBAD);
    let mut buf = Vec::new();
    for _ in 0..100 {
        let rows = 1 + rng.index(8);
        let obs_len = 1 + rng.index(16);
        let hidden = 1 + rng.index(8);
        let obs: Vec<f32> = (0..rows * obs_len).map(|_| rng.next_f32()).collect();
        let h = vec![0.5f32; rows * hidden];
        let c = vec![0.5f32; rows * hidden];
        frame::encode_submit(&mut buf, rng.next_u64(), rows, &obs, &h, &c);
        let fr = strip_len(&buf).to_vec();

        // Truncated header.
        let cut = rng.index(frame::HEADER_LEN);
        assert!(frame::parse_header(&fr[..cut]).is_err());
        // Bad magic.
        let mut bad = fr.clone();
        bad[rng.index(2)] ^= 0x40;
        assert!(frame::parse_header(&bad).is_err());
        // Unknown kind.
        let mut bad = fr.clone();
        bad[2] = 7 + rng.index(200) as u8;
        assert!(frame::parse_header(&bad).is_err());
        // Truncated payload: length disagrees with rows * dims.
        let (mut o2, mut h2, mut c2) = (Vec::new(), Vec::new(), Vec::new());
        let pl = frame::payload(&fr);
        let cut = rng.index(pl.len());
        assert!(frame::decode_submit(
            &pl[..cut],
            rows,
            obs_len,
            hidden,
            &mut o2,
            &mut h2,
            &mut c2
        )
        .is_err());
        // Truncated sequence payloads never panic either.
        let seq = Sequence {
            obs: vec![1.0; 2 * obs_len],
            actions: vec![0; 2],
            rewards: vec![0.0; 2],
            discounts: vec![0.9; 2],
            h0: vec![0.0; hidden],
            c0: vec![0.0; hidden],
            actor_id: 0,
            valid_len: 2,
        };
        frame::encode_sequence(&mut buf, &seq);
        let fr = strip_len(&buf);
        let pl = frame::payload(fr);
        let cut = rng.index(pl.len());
        let mut out = Sequence::default();
        assert!(frame::decode_sequence(&pl[..cut], obs_len, hidden, &mut out).is_err());
    }
}

// ---------------------------------------------------------------------------
// Loopback fleet harness
// ---------------------------------------------------------------------------

fn uds_addr(tag: &str) -> Addr {
    let path = std::env::temp_dir().join(format!(
        "rlarch_fleet_{tag}_{}.sock",
        std::process::id()
    ));
    let _ = std::fs::remove_file(&path);
    Addr::Unix(path)
}

fn wait_for(mut cond: impl FnMut() -> bool, what: &str) {
    let t0 = Instant::now();
    while !cond() {
        assert!(
            t0.elapsed() < Duration::from_secs(10),
            "timed out waiting for {what}"
        );
        std::thread::sleep(Duration::from_millis(10));
    }
}

/// The deterministic fleet workload: 2 actors x 3 env slots on catch,
/// a batch cap below the slot count (multi-row submissions split).
fn fleet_cfg() -> (SystemConfig, ModelDims) {
    let mut cfg = SystemConfig::default();
    cfg.env.name = "catch".into();
    cfg.env.step_cost_us = 0;
    cfg.env.frame_stack = 4;
    cfg.actors.num_actors = 2;
    cfg.actors.envs_per_actor = 3;
    cfg.learner.burn_in = 2;
    cfg.learner.unroll_len = 4;
    cfg.learner.seq_overlap = 2;
    cfg.learner.train_batch = 4;
    cfg.batcher.max_batch = 8;
    cfg.batcher.batch_sizes = vec![1, 8];
    cfg.batcher.timeout_us = 200;
    let dims = ModelDims {
        obs_len: 400,
        hidden: 8,
        num_actions: 4,
        seq_len: 6,
        train_batch: 4,
    };
    (cfg, dims)
}

/// Group a replay snapshot by emitting env slot; per-slot order is
/// emission order, which both paths must preserve.
fn by_slot(seqs: &[Arc<Sequence>]) -> BTreeMap<usize, Vec<Arc<Sequence>>> {
    let mut m: BTreeMap<usize, Vec<Arc<Sequence>>> = BTreeMap::new();
    for s in seqs {
        m.entry(s.actor_id).or_default().push(s.clone());
    }
    m
}

#[test]
fn loopback_uds_fleet_matches_the_in_process_central_path() {
    // Tentpole acceptance: a 1-server + 2-actor loopback fleet run over
    // UDS must produce the same replay stream (per env slot) as the
    // same actors running in-process against the same central batcher.
    let (cfg, dims) = fleet_cfg();
    let rounds = 60u64;

    // --- In-process reference: 2 actor threads, one batcher, local
    // replay (the seed central path).
    let reference = {
        let backend = Backend::Mock(Arc::new(MockModel::new(dims, 11)));
        let metrics = Registry::new();
        let replay = Arc::new(SequenceReplay::new(ReplayConfig {
            capacity: 4_096,
            ..Default::default()
        }));
        let (batcher, handle) =
            Batcher::spawn(cfg.batcher.clone(), backend, metrics.clone());
        let stats: Vec<_> = std::thread::scope(|s| {
            let joins: Vec<_> = (0..cfg.actors.num_actors)
                .map(|id| {
                    let cfg = cfg.clone();
                    let handle = handle.clone();
                    let metrics = metrics.clone();
                    let replay = replay.clone();
                    s.spawn(move || {
                        let policy: Box<dyn PolicyClient> = Box::new(
                            CentralClient::new(handle, id, dims, &metrics),
                        );
                        run_actor(ActorArgs {
                            id,
                            cfg,
                            dims,
                            policy,
                            replay,
                            metrics,
                            shutdown: ShutdownToken::new(),
                            max_rounds: Some(rounds),
                        })
                        .unwrap()
                    })
                })
                .collect();
            joins.into_iter().map(|j| j.join().unwrap()).collect()
        });
        drop(handle);
        batcher.join();
        (stats, replay.snapshot())
    };

    // --- Loopback fleet: same batcher config behind a FleetServer on a
    // UDS socket; the same 2 actors run as remote workers with their
    // own registries and shutdown tokens (process stand-ins).
    let addr = uds_addr("equiv");
    let backend = Backend::Mock(Arc::new(MockModel::new(dims, 11)));
    let server_metrics = Registry::new();
    let server_shutdown = ShutdownToken::new();
    let replay = Arc::new(SequenceReplay::new(ReplayConfig {
        capacity: 4_096,
        ..Default::default()
    }));
    let (batcher, handle) =
        Batcher::spawn(cfg.batcher.clone(), backend, server_metrics.clone());
    let listener = Listener::bind(&addr).unwrap();
    let server = FleetServer::spawn(
        listener,
        handle.clone(),
        replay.clone(),
        FleetServerOpts::default(),
        server_metrics.clone(),
        server_shutdown.clone(),
    );

    let worker_metrics = Registry::new();
    let worker_shutdown = ShutdownToken::new();
    let opts = RemoteClientOpts::default();
    let ingest = Arc::new(
        RemoteIngest::connect(
            &addr,
            dims,
            &opts,
            &worker_metrics,
            worker_shutdown.clone(),
        )
        .unwrap(),
    );
    let fleet_stats: Vec<_> = std::thread::scope(|s| {
        let joins: Vec<_> = (0..cfg.actors.num_actors)
            .map(|id| {
                let cfg = cfg.clone();
                let addr = addr.clone();
                let metrics = worker_metrics.clone();
                let shutdown = worker_shutdown.clone();
                let ingest = ingest.clone();
                s.spawn(move || {
                    let policy: Box<dyn PolicyClient> = Box::new(
                        RemoteClient::connect(
                            &addr,
                            id,
                            dims,
                            opts,
                            &metrics,
                            shutdown.clone(),
                        )
                        .unwrap(),
                    );
                    run_actor(ActorArgs {
                        id,
                        cfg,
                        dims,
                        policy,
                        replay: ingest,
                        metrics,
                        shutdown,
                        max_rounds: Some(rounds),
                    })
                    .unwrap()
                })
            })
            .collect();
        joins.into_iter().map(|j| j.join().unwrap()).collect()
    });
    ingest.goodbye();
    // Everything the workers sent is in flight at most briefly; wait
    // for the ingest connection to land every sequence, then drain.
    let want = reference.1.len() as u64;
    let rx = server_metrics.counter("fleet.rx_sequences");
    wait_for(|| rx.get() >= want, "all sequences to arrive");
    server_shutdown.signal();
    server.join();
    drop(handle);
    batcher.join();

    // Same per-actor stats...
    for (a, b) in reference.0.iter().zip(&fleet_stats) {
        assert_eq!(a.env_steps, b.env_steps);
        assert_eq!(a.episodes, b.episodes);
    }
    // ...and the same replay stream, slot by slot, byte for byte.
    let golden = by_slot(&reference.1);
    let fleet = by_slot(&replay.snapshot());
    assert!(!golden.is_empty(), "reference produced no sequences");
    assert_eq!(
        fleet.keys().collect::<Vec<_>>(),
        golden.keys().collect::<Vec<_>>()
    );
    for (slot, gold) in &golden {
        let got = &fleet[slot];
        assert_eq!(got.len(), gold.len(), "slot {slot} sequence count");
        for (i, (a, b)) in got.iter().zip(gold).enumerate() {
            assert_eq!(a, b, "slot {slot} sequence {i} diverged");
        }
    }

    // The fleet telemetry was live on both sides.
    assert!(worker_metrics.counter("fleet.tx_frames").get() > 0);
    assert!(worker_metrics.counter("fleet.tx_bytes").get() > 0);
    let snap = worker_metrics.snapshot();
    assert!(snap["fleet.rtt_seconds.count"] > 0.0, "client RTT timer");
    assert_eq!(server_metrics.counter("fleet.rx_sequences").get(), want);
    assert!(server_metrics.counter("fleet.accepts").get() >= 3); // 2 infer + 1 ingest
    assert_eq!(server_metrics.counter("fleet.disconnects").get(), 0);
    let ssnap = server_metrics.snapshot();
    assert!(ssnap["fleet.encode_seconds.count"] > 0.0);
    assert!(ssnap["fleet.decode_seconds.count"] > 0.0);
    assert_eq!(ssnap["fleet.connections"], 0.0, "all connections drained");
}

fn policy_dims() -> ModelDims {
    ModelDims {
        obs_len: 8,
        hidden: 4,
        num_actions: 3,
        seq_len: 4,
        train_batch: 2,
    }
}

/// One manual split-phase round-trip through a remote client.
fn roundtrip(client: &mut RemoteClient, d: &ModelDims, tag: f32) {
    let obs = vec![tag; d.obs_len];
    let h = vec![0.0f32; d.hidden];
    let c = vec![0.0f32; d.hidden];
    client.submit(0, 1, &obs, &h, &c).unwrap();
    let mut q = vec![0.0f32; d.num_actions];
    let (mut h2, mut c2) = (vec![0.0f32; d.hidden], vec![0.0f32; d.hidden]);
    client.wait(0, &mut q, &mut h2, &mut c2).unwrap();
    assert!(q.iter().all(|v| v.is_finite()));
}

struct TestServer {
    server: Option<FleetServer>,
    batcher: Option<Batcher>,
    handle: Option<rlarch::coordinator::BatcherHandle>,
    metrics: Registry,
    shutdown: ShutdownToken,
    addr: Addr,
}

impl TestServer {
    fn start(tag: &str, d: ModelDims, batcher_cfg: BatcherConfig, opts: FleetServerOpts) -> Self {
        let addr = uds_addr(tag);
        let backend = Backend::Mock(Arc::new(MockModel::new(d, 7)));
        let metrics = Registry::new();
        let shutdown = ShutdownToken::new();
        let sink = Arc::new(SequenceReplay::new(ReplayConfig {
            capacity: 64,
            ..Default::default()
        }));
        let (batcher, handle) = Batcher::spawn(batcher_cfg, backend, metrics.clone());
        let listener = Listener::bind(&addr).unwrap();
        let server = FleetServer::spawn(
            listener,
            handle.clone(),
            sink,
            opts,
            metrics.clone(),
            shutdown.clone(),
        );
        TestServer {
            server: Some(server),
            batcher: Some(batcher),
            handle: Some(handle),
            metrics,
            shutdown,
            addr,
        }
    }

    fn stop(mut self) {
        self.shutdown.signal();
        self.server.take().unwrap().join();
        drop(self.handle.take());
        self.batcher.take().unwrap().join();
    }
}

#[test]
fn killed_worker_is_counted_and_survivors_plus_rejoiners_proceed() {
    // Kill-and-reconnect e2e: an uncleanly dying connection is counted
    // as a disconnect (its in-flight replies shed, not stalled), the
    // other connection keeps round-tripping, and a later connect is
    // counted as the reconnect and serves traffic normally.
    let d = policy_dims();
    let srv = TestServer::start(
        "kill",
        d,
        BatcherConfig::default(),
        FleetServerOpts::default(),
    );
    let opts = RemoteClientOpts::default();

    let wm = Registry::new();
    let mut survivor = RemoteClient::connect(
        &srv.addr,
        0,
        d,
        opts,
        &wm,
        ShutdownToken::new(),
    )
    .unwrap();
    roundtrip(&mut survivor, &d, 0.25);

    // The victim: a raw connection that completes the handshake, then
    // dies without a goodbye (a killed worker process).
    {
        let mut stream = dial(&srv.addr, 3, 10, None).unwrap();
        let mut buf = Vec::new();
        frame::encode_hello(
            &mut buf,
            &frame::Hello {
                role: Role::Infer,
                actor_id: 1,
                obs_len: d.obs_len as u32,
                hidden: d.hidden as u32,
                num_actions: d.num_actions as u32,
                seq_len: d.seq_len as u32,
            },
        );
        stream.write_all(&buf).unwrap();
        let conns = srv.metrics.gauge("fleet.connections");
        wait_for(|| conns.get() >= 2.0, "victim connection to register");
        // drop(stream): the unclean death.
    }
    let disconnects = srv.metrics.counter("fleet.disconnects");
    wait_for(|| disconnects.get() >= 1, "the death to be counted");

    // The survivor never noticed.
    roundtrip(&mut survivor, &d, 0.5);

    // The rejoiner: a fresh connect after a recorded death is the
    // kill-and-reconnect signal, and serves traffic like any other.
    let mut rejoiner = RemoteClient::connect(
        &srv.addr,
        1,
        d,
        opts,
        &wm,
        ShutdownToken::new(),
    )
    .unwrap();
    let reconnects = srv.metrics.counter("fleet.reconnects");
    wait_for(|| reconnects.get() >= 1, "the reconnect to be counted");
    roundtrip(&mut rejoiner, &d, 0.75);

    drop(survivor);
    drop(rejoiner);
    srv.stop();
}

#[test]
fn over_budget_submissions_are_shed_and_transparently_retried() {
    // Backpressure acceptance: with a 1-row in-flight budget and slow
    // inference, the second of two back-to-back submissions must be
    // shed (counter, error reply) — and the client's shed-retry loop
    // must still complete both round-trips without error.
    let d = policy_dims();
    let bcfg = BatcherConfig {
        max_batch: 4,
        timeout_us: 200,
        batch_sizes: vec![1, 4],
    };
    let addr = uds_addr("shed");
    let backend = Backend::Mock(Arc::new(
        MockModel::new(d, 7).with_infer_latency(Duration::from_millis(40)),
    ));
    let metrics = Registry::new();
    let shutdown = ShutdownToken::new();
    let sink = Arc::new(SequenceReplay::new(ReplayConfig {
        capacity: 64,
        ..Default::default()
    }));
    let (batcher, handle) = Batcher::spawn(bcfg, backend, metrics.clone());
    let listener = Listener::bind(&addr).unwrap();
    let server = FleetServer::spawn(
        listener,
        handle.clone(),
        sink,
        FleetServerOpts {
            max_inflight_rows: 1,
            insert_batch: 1,
        },
        metrics.clone(),
        shutdown.clone(),
    );

    let wm = Registry::new();
    let mut client = RemoteClient::connect(
        &addr,
        0,
        d,
        RemoteClientOpts::default(),
        &wm,
        ShutdownToken::new(),
    )
    .unwrap();
    let obs = vec![0.5f32; d.obs_len];
    let h = vec![0.0f32; d.hidden];
    let c = vec![0.0f32; d.hidden];
    // Two tickets in flight against a 1-row budget: the second arrives
    // while the first sits under 40ms of inference latency → shed.
    client.submit(0, 1, &obs, &h, &c).unwrap();
    client.submit(1, 1, &obs, &h, &c).unwrap();
    let mut q = vec![0.0f32; d.num_actions];
    let (mut h2, mut c2) = (vec![0.0f32; d.hidden], vec![0.0f32; d.hidden]);
    client.wait(0, &mut q, &mut h2, &mut c2).unwrap();
    client.wait(1, &mut q, &mut h2, &mut c2).unwrap();
    assert!(
        metrics.counter("fleet.shed_rows").get() >= 1,
        "the over-budget submission was shed"
    );
    assert!(
        wm.counter("fleet.resubmits").get() >= 1,
        "the client retried the shed ticket"
    );

    drop(client);
    shutdown.signal();
    server.join();
    drop(handle);
    batcher.join();
}
