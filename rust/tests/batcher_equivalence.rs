//! Central-path equivalence: the pooled, bucketed batcher (PR 5's slab
//! protocol — recycled input slabs, persistent reply mailboxes,
//! `Arc`-shared output slabs, padded-bucket launches) must replay the
//! seed batcher's reply stream **byte-identically**. Padding changes
//! launch shapes, pooling changes where buffers live; neither may
//! change a single reply byte.
//!
//! The golden reference is a verbatim replica of the pre-pooling
//! batcher (per-submission `std::sync::mpsc` reply channels, owned
//! `Vec` payloads, a fresh `InferRequest` + routes `Vec` per batch,
//! per-chunk `to_vec` reply copies, exact-shape launches), driven with
//! the same submissions. A property test randomizes rows / max_batch /
//! timeout / bucket ladders / submit-wait interleavings; a second test
//! pins the inference-failure drain path.

use rlarch::config::BatcherConfig;
use rlarch::coordinator::Batcher;
use rlarch::metrics::Registry;
use rlarch::policy::{CentralClient, PolicyClient};
use rlarch::runtime::{Backend, MockModel, ModelDims};
use rlarch::util::quickcheck::{forall, prop_assert};
use std::sync::Arc;

/// Verbatim replica of the seed batcher (PR 2 protocol). Kept minimal:
/// no metrics, exact-shape launches, flush at `max_batch` rows or the
/// collection timeout — the flush policy the pooled batcher must
/// reproduce bit-for-bit at `batch_sizes = [max_batch]`.
mod seed {
    use rlarch::config::BatcherConfig;
    use rlarch::runtime::{Backend, InferRequest};
    use std::collections::VecDeque;
    use std::sync::mpsc;
    use std::thread::JoinHandle;
    use std::time::{Duration, Instant};

    pub struct SeedItem {
        pub rows: usize,
        pub obs: Vec<f32>,
        pub h: Vec<f32>,
        pub c: Vec<f32>,
        pub reply: mpsc::Sender<SeedChunk>,
    }

    pub struct SeedChunk {
        pub slot0: usize,
        pub rows: usize,
        pub result: Result<SeedData, String>,
    }

    pub struct SeedData {
        pub q: Vec<f32>,
        pub h: Vec<f32>,
        pub c: Vec<f32>,
    }

    pub struct SeedBatcher {
        join: Option<JoinHandle<()>>,
    }

    impl SeedBatcher {
        pub fn spawn(
            cfg: BatcherConfig,
            backend: Backend,
        ) -> (SeedBatcher, mpsc::Sender<SeedItem>) {
            let (tx, rx) = mpsc::channel::<SeedItem>();
            let join = std::thread::Builder::new()
                .name("seed-batcher-replica".into())
                .spawn(move || run(cfg, backend, rx))
                .expect("spawn seed batcher");
            (SeedBatcher { join: Some(join) }, tx)
        }

        pub fn join(mut self) {
            if let Some(j) = self.join.take() {
                let _ = j.join();
            }
        }
    }

    struct Open {
        item: SeedItem,
        consumed: usize,
    }

    fn run(cfg: BatcherConfig, backend: Backend, rx: mpsc::Receiver<SeedItem>) {
        let dims = backend.dims();
        let timeout = Duration::from_micros(cfg.timeout_us);
        let mut queue: VecDeque<Open> = VecDeque::new();
        let mut rows_avail = 0usize;
        let push = |queue: &mut VecDeque<Open>, rows_avail: &mut usize, item: SeedItem| {
            *rows_avail += item.rows;
            queue.push_back(Open { item, consumed: 0 });
        };

        loop {
            if rows_avail == 0 {
                match rx.recv() {
                    Ok(item) => push(&mut queue, &mut rows_avail, item),
                    Err(_) => return,
                }
            }
            let deadline = Instant::now() + timeout;
            while rows_avail < cfg.max_batch {
                let now = Instant::now();
                if now >= deadline {
                    break;
                }
                match rx.recv_timeout(deadline - now) {
                    Ok(item) => push(&mut queue, &mut rows_avail, item),
                    Err(mpsc::RecvTimeoutError::Timeout) => break,
                    Err(mpsc::RecvTimeoutError::Disconnected) => break,
                }
            }

            let n = rows_avail.min(cfg.max_batch);
            let mut req = InferRequest {
                n,
                h: Vec::with_capacity(n * dims.hidden),
                c: Vec::with_capacity(n * dims.hidden),
                obs: Vec::with_capacity(n * dims.obs_len),
            };
            let mut routes: Vec<(mpsc::Sender<SeedChunk>, usize, usize)> = Vec::new();
            let mut taken = 0usize;
            while taken < n {
                let open = queue.front_mut().expect("rows_avail tracks queue rows");
                let k = (open.item.rows - open.consumed).min(n - taken);
                let (a, b) = (open.consumed, open.consumed + k);
                req.h
                    .extend_from_slice(&open.item.h[a * dims.hidden..b * dims.hidden]);
                req.c
                    .extend_from_slice(&open.item.c[a * dims.hidden..b * dims.hidden]);
                req.obs
                    .extend_from_slice(&open.item.obs[a * dims.obs_len..b * dims.obs_len]);
                routes.push((open.item.reply.clone(), open.consumed, k));
                open.consumed += k;
                taken += k;
                if open.consumed == open.item.rows {
                    queue.pop_front();
                }
            }
            rows_avail -= n;

            match backend.infer(req) {
                Ok(out) => {
                    let a = dims.num_actions;
                    let hd = dims.hidden;
                    let mut off = 0usize;
                    for (tx, slot0, k) in routes {
                        let _ = tx.send(SeedChunk {
                            slot0,
                            rows: k,
                            result: Ok(SeedData {
                                q: out.q[off * a..(off + k) * a].to_vec(),
                                h: out.h[off * hd..(off + k) * hd].to_vec(),
                                c: out.c[off * hd..(off + k) * hd].to_vec(),
                            }),
                        });
                        off += k;
                    }
                }
                Err(e) => {
                    let msg = e.to_string();
                    for (tx, slot0, k) in routes {
                        let _ = tx.send(SeedChunk {
                            slot0,
                            rows: k,
                            result: Err(msg.clone()),
                        });
                    }
                    for open in queue.drain(..) {
                        let _ = open.item.reply.send(SeedChunk {
                            slot0: open.consumed,
                            rows: open.item.rows - open.consumed,
                            result: Err(msg.clone()),
                        });
                    }
                    return;
                }
            }
        }
    }
}

fn dims() -> ModelDims {
    ModelDims {
        obs_len: 6,
        hidden: 3,
        num_actions: 2,
        seq_len: 4,
        train_batch: 2,
    }
}

/// One randomized submission's payload.
struct Sub {
    rows: usize,
    obs: Vec<f32>,
    h: Vec<f32>,
    c: Vec<f32>,
}

type RowSlabs = (Vec<f32>, Vec<f32>, Vec<f32>);

/// Drive the seed replica: submit in windows of `window`, gather each
/// submission's chunks into slot-ordered row slabs.
fn drive_seed(
    cfg: &BatcherConfig,
    backend: &Backend,
    subs: &[Sub],
    window: usize,
) -> Vec<Result<RowSlabs, String>> {
    let d = dims();
    let (batcher, tx) = seed::SeedBatcher::spawn(cfg.clone(), backend.clone());
    let mut out = Vec::new();
    for group in subs.chunks(window) {
        let mut rxs = Vec::new();
        for sub in group {
            let (rtx, rrx) = std::sync::mpsc::channel();
            // A dead replica (failure-injection runs) refuses the send;
            // record it like any other lost submission.
            let sent = tx
                .send(seed::SeedItem {
                    rows: sub.rows,
                    obs: sub.obs.clone(),
                    h: sub.h.clone(),
                    c: sub.c.clone(),
                    reply: rtx,
                })
                .is_ok();
            rxs.push((sub.rows, rrx, sent));
        }
        for (rows, rrx, sent) in rxs {
            let mut q = vec![0.0f32; rows * d.num_actions];
            let mut h = vec![0.0f32; rows * d.hidden];
            let mut c = vec![0.0f32; rows * d.hidden];
            let mut done = 0usize;
            let mut failed = if sent {
                None
            } else {
                Some("seed batcher gone".to_string())
            };
            while failed.is_none() && done < rows {
                let chunk = match rrx.recv() {
                    Ok(chunk) => chunk,
                    Err(_) => {
                        failed = Some("seed batcher gone".to_string());
                        break;
                    }
                };
                match chunk.result {
                    Ok(data) => {
                        let (s, k) = (chunk.slot0, chunk.rows);
                        q[s * d.num_actions..(s + k) * d.num_actions]
                            .copy_from_slice(&data.q);
                        h[s * d.hidden..(s + k) * d.hidden].copy_from_slice(&data.h);
                        c[s * d.hidden..(s + k) * d.hidden].copy_from_slice(&data.c);
                        done += k;
                    }
                    Err(e) => {
                        failed = Some(e);
                        break;
                    }
                }
            }
            out.push(match failed {
                Some(e) => Err(e),
                None => Ok((q, h, c)),
            });
        }
    }
    drop(tx);
    batcher.join();
    out
}

/// Drive the pooled batcher through a real `CentralClient` with the
/// same windowed interleaving (tickets 0..window in flight at once —
/// the mailbox demux path).
fn drive_pooled(
    cfg: &BatcherConfig,
    backend: &Backend,
    subs: &[Sub],
    window: usize,
) -> Vec<Result<RowSlabs, String>> {
    let d = dims();
    let metrics = Registry::new();
    let (batcher, handle) = Batcher::spawn(cfg.clone(), backend.clone(), metrics);
    let client_metrics = Registry::new();
    let mut client = CentralClient::new(handle.clone(), 0, d, &client_metrics);
    let mut out = Vec::new();
    'outer: for group in subs.chunks(window) {
        for (t, sub) in group.iter().enumerate() {
            if let Err(e) = client.submit(t, sub.rows, &sub.obs, &sub.h, &sub.c) {
                // Batcher already died (failure-injection runs): record
                // the whole group as failed — none of its results have
                // been pushed yet — and move on.
                for _ in group.iter() {
                    out.push(Err(e.to_string()));
                }
                continue 'outer;
            }
        }
        for (t, sub) in group.iter().enumerate() {
            let mut q = vec![0.0f32; sub.rows * d.num_actions];
            let mut h = vec![0.0f32; sub.rows * d.hidden];
            let mut c = vec![0.0f32; sub.rows * d.hidden];
            out.push(match client.wait(t, &mut q, &mut h, &mut c) {
                Ok(()) => Ok((q, h, c)),
                Err(e) => Err(e.to_string()),
            });
        }
    }
    drop(client);
    drop(handle);
    batcher.join();
    out
}

fn random_sub(g: &mut rlarch::util::quickcheck::Gen, max_rows: usize) -> Sub {
    let d = dims();
    let rows = g.usize(1..max_rows + 1);
    let mut fill = |len: usize| -> Vec<f32> {
        (0..len).map(|_| g.rng().next_f32() - 0.5).collect()
    };
    Sub {
        obs: fill(rows * d.obs_len),
        h: fill(rows * d.hidden),
        c: fill(rows * d.hidden),
        rows,
    }
}

#[test]
fn prop_pooled_bucketed_batcher_replays_seed_reply_stream_byte_for_byte() {
    // Randomized rows / max_batch / timeout / ladder / interleaving:
    // every submission's scattered (q, h', c') must equal the seed
    // replica's bit-for-bit. The `[max_batch]` ladder (the acceptance
    // knob) runs every case; a random denser ladder runs on top.
    forall(15, |g| {
        let max_batch = g.usize(1..10);
        let timeout_us = *g.pick(&[0u64, 100, 1_000, 5_000]);
        let window = g.usize(1..4);
        let n_subs = g.usize(6..12);
        let subs: Vec<Sub> = (0..n_subs)
            .map(|_| random_sub(g, 2 * max_batch + 3))
            .collect();
        let backend = Backend::Mock(Arc::new(MockModel::new(dims(), 13)));

        let seed_cfg = BatcherConfig {
            max_batch,
            timeout_us,
            batch_sizes: vec![max_batch],
        };
        let golden = drive_seed(&seed_cfg, &backend, &subs, window);

        // Ladder 1: the seed flush policy knob, buckets = [max_batch].
        let mut ladders = vec![vec![max_batch]];
        // Ladder 2: a random denser ladder ending at the cap.
        let mut ladder = vec![max_batch];
        for _ in 0..g.usize(0..3) {
            if max_batch > 1 {
                ladder.push(g.usize(1..max_batch));
            }
        }
        ladder.sort_unstable();
        ladder.dedup();
        ladders.push(ladder);

        for batch_sizes in ladders {
            let cfg = BatcherConfig {
                max_batch,
                timeout_us,
                batch_sizes: batch_sizes.clone(),
            };
            let got = drive_pooled(&cfg, &backend, &subs, window);
            prop_assert(
                got.len() == golden.len(),
                &format!("submission count diverged (ladder {batch_sizes:?})"),
            )?;
            for (i, (a, b)) in got.iter().zip(&golden).enumerate() {
                let (a, b) = match (a, b) {
                    (Ok(a), Ok(b)) => (a, b),
                    other => {
                        return Err(format!(
                            "submission {i} health diverged: {:?} (ladder \
                             {batch_sizes:?}, mb {max_batch}, to {timeout_us})",
                            other.0.is_ok()
                        ))
                    }
                };
                prop_assert(
                    a == b,
                    &format!(
                        "submission {i} reply bytes diverged (ladder \
                         {batch_sizes:?}, mb {max_batch}, to {timeout_us}, \
                         window {window})"
                    ),
                )?;
            }
        }
        Ok(())
    });
}

#[test]
fn pooled_bucket_cap_ladder_pads_launches_without_changing_one_reply_byte() {
    // Deterministic acceptance pin: cap-bucket ladder ([max_batch]) on
    // a fixed workload with partial flushes and an oversized split —
    // padding must be *observable* (padded_rows > 0) while the reply
    // stream matches the exact-shape seed replica byte-for-byte.
    let d = dims();
    let backend = Backend::Mock(Arc::new(MockModel::new(d, 29)));
    let mut g = rlarch::util::quickcheck::Gen::new(0xB0CE7);
    let subs: Vec<Sub> = [1usize, 3, 9, 4, 2, 6, 1]
        .iter()
        .map(|&rows| {
            let mut s = random_sub(&mut g, 1);
            s.rows = rows;
            s.obs = (0..rows * d.obs_len)
                .map(|i| (i as f32 * 0.01).sin())
                .collect();
            s.h = (0..rows * d.hidden).map(|i| (i as f32 * 0.02).cos()).collect();
            s.c = (0..rows * d.hidden).map(|i| i as f32 * 0.001).collect();
            s
        })
        .collect();
    let cfg = BatcherConfig {
        max_batch: 4,
        timeout_us: 300,
        batch_sizes: vec![4],
    };
    let golden = drive_seed(&cfg, &backend, &subs, 2);

    let metrics = Registry::new();
    let (batcher, handle) = Batcher::spawn(cfg, backend, metrics.clone());
    let mut client = CentralClient::new(handle.clone(), 0, d, &metrics);
    let mut got = Vec::new();
    for group in subs.chunks(2) {
        for (t, sub) in group.iter().enumerate() {
            client.submit(t, sub.rows, &sub.obs, &sub.h, &sub.c).unwrap();
        }
        for (t, sub) in group.iter().enumerate() {
            let mut q = vec![0.0f32; sub.rows * d.num_actions];
            let mut h = vec![0.0f32; sub.rows * d.hidden];
            let mut c = vec![0.0f32; sub.rows * d.hidden];
            client.wait(t, &mut q, &mut h, &mut c).unwrap();
            got.push((q, h, c));
        }
    }
    drop(client);
    drop(handle);
    batcher.join();

    assert!(
        metrics.counter("batcher.padded_rows").get() > 0,
        "the cap ladder must actually pad partial flushes"
    );
    assert_eq!(got.len(), golden.len());
    for (i, (a, b)) in got.iter().zip(&golden).enumerate() {
        let b = b.as_ref().expect("seed replica healthy");
        assert_eq!(a, b, "submission {i} diverged under cap-bucket padding");
    }
}

#[test]
fn inference_failure_drains_both_batchers_identically() {
    // The drain path: a failing backend must error every in-flight and
    // queued submission with the fault, record it as first_error, and
    // name it on post-mortem submissions — in both implementations.
    let d = dims();
    let fault = "injected central fault";
    let backend =
        Backend::Mock(Arc::new(MockModel::new(d, 3).with_infer_error(fault)));
    let mut g = rlarch::util::quickcheck::Gen::new(0xFA17);
    let subs: Vec<Sub> = (0..5).map(|_| random_sub(&mut g, 9)).collect();
    let cfg = BatcherConfig {
        max_batch: 4,
        timeout_us: 200,
        batch_sizes: vec![4],
    };

    let golden = drive_seed(&cfg, &backend, &subs, 3);
    for (i, r) in golden.iter().enumerate() {
        let e = r.as_ref().expect_err("seed replica must fail");
        assert!(
            e.contains(fault) || e.contains("gone"),
            "seed submission {i}: {e}"
        );
    }

    let metrics = Registry::new();
    let (batcher, handle) = Batcher::spawn(cfg, backend, metrics.clone());
    let got = {
        let mut client = CentralClient::new(handle.clone(), 0, d, &metrics);
        let mut out = Vec::new();
        for group in subs.chunks(3) {
            let mut submitted = Vec::new();
            for (t, sub) in group.iter().enumerate() {
                match client.submit(t, sub.rows, &sub.obs, &sub.h, &sub.c) {
                    Ok(()) => submitted.push((t, sub.rows)),
                    Err(e) => out.push(Err::<RowSlabs, String>(e.to_string())),
                }
            }
            for (t, rows) in submitted {
                let mut q = vec![0.0f32; rows * d.num_actions];
                let mut h = vec![0.0f32; rows * d.hidden];
                let mut c = vec![0.0f32; rows * d.hidden];
                out.push(match client.wait(t, &mut q, &mut h, &mut c) {
                    Ok(()) => Ok((q, h, c)),
                    Err(e) => Err(e.to_string()),
                });
            }
        }
        out
    };
    assert_eq!(got.len(), subs.len());
    for (i, r) in got.iter().enumerate() {
        let e = r.as_ref().expect_err("pooled batcher must fail every waiter");
        assert!(
            e.contains(fault),
            "pooled submission {i} lost the fault message: {e}"
        );
    }
    // Both record the same first error; post-mortem submits name it.
    assert_eq!(metrics.counter("batcher.errors").get(), 1);
    assert_eq!(handle.first_error().as_deref(), Some(fault));
    let post = handle
        .infer(0, vec![0.1; d.obs_len], vec![0.0; d.hidden], vec![0.0; d.hidden])
        .unwrap_err()
        .to_string();
    assert!(post.contains(fault), "post-mortem lost the fault: {post}");
    batcher.join();
}
