//! Sharded-replay equivalence: `shards = 1` must reproduce the seed's
//! single-ring prioritized buffer bit-for-bit — same RNG stream, same
//! sampled slots, same priorities — asserted against a verbatim replica
//! of the seed implementation (the PR 2 golden-replica pattern). The
//! batched-ingest tests extend the same contract to `IngestQueue`:
//! `insert_batch = 1` is the seed `add` stream exactly, and any batch
//! size from a single producer preserves the global insert order.

use rlarch::replay::{IngestQueue, ReplayConfig, SequenceReplay, SumTree};
use rlarch::rl::Sequence;
use rlarch::util::prng::Pcg32;
use std::sync::{Arc, Mutex};

// ---------------------------------------------------------------------------
// Verbatim replica of the seed `SequenceReplay` (pre-sharding): one ring
// + one sum tree behind one mutex, stratified sampling over equal mass
// segments, max-priority inserts.
// ---------------------------------------------------------------------------

struct SeedInner {
    slots: Vec<Option<Arc<Sequence>>>,
    tree: SumTree,
    write: usize,
    len: usize,
    max_raw_priority: f64,
}

struct SeedReplay {
    capacity: usize,
    alpha: f64,
    min_priority: f64,
    inner: Mutex<SeedInner>,
}

struct SeedSampled {
    sequences: Vec<Arc<Sequence>>,
    slots: Vec<usize>,
}

impl SeedReplay {
    fn new(capacity: usize, alpha: f64, min_priority: f64) -> Self {
        Self {
            capacity,
            alpha,
            min_priority,
            inner: Mutex::new(SeedInner {
                slots: (0..capacity).map(|_| None).collect(),
                tree: SumTree::new(capacity),
                write: 0,
                len: 0,
                max_raw_priority: 1.0,
            }),
        }
    }

    fn shaped(&self, raw: f64) -> f64 {
        raw.max(self.min_priority).powf(self.alpha)
    }

    fn add(&self, seq: Sequence) {
        let mut g = self.inner.lock().unwrap();
        let idx = g.write;
        let raw = g.max_raw_priority;
        let prio = self.shaped(raw);
        g.slots[idx] = Some(Arc::new(seq));
        g.tree.set(idx, prio);
        g.write = (g.write + 1) % self.capacity;
        g.len = (g.len + 1).min(self.capacity);
    }

    fn len(&self) -> usize {
        self.inner.lock().unwrap().len
    }

    fn sample(&self, batch: usize, rng: &mut Pcg32) -> Option<SeedSampled> {
        let g = self.inner.lock().unwrap();
        if g.len < batch || g.tree.total() <= 0.0 {
            return None;
        }
        let total = g.tree.total();
        let seg = total / batch as f64;
        let mut sequences = Vec::with_capacity(batch);
        let mut slots = Vec::with_capacity(batch);
        for i in 0..batch {
            let u = (i as f64 + rng.next_f64()) * seg;
            let slot = g.tree.sample(u);
            match &g.slots[slot] {
                Some(seq) => {
                    sequences.push(seq.clone());
                    slots.push(slot);
                }
                None => unreachable!("sampled an empty slot {slot}"),
            }
        }
        Some(SeedSampled { sequences, slots })
    }

    fn update_priorities(&self, slots: &[usize], raw_priorities: &[f32]) {
        let mut g = self.inner.lock().unwrap();
        for (&slot, &p) in slots.iter().zip(raw_priorities) {
            if g.slots[slot].is_none() {
                continue;
            }
            let raw = (p as f64).max(self.min_priority);
            g.max_raw_priority = g.max_raw_priority.max(raw);
            let shaped = self.shaped(raw);
            g.tree.set(slot, shaped);
        }
    }

    fn priority_of(&self, slot: usize) -> f64 {
        self.inner.lock().unwrap().tree.get(slot)
    }

    fn snapshot_tags(&self) -> Vec<f32> {
        let g = self.inner.lock().unwrap();
        let start = if g.len == self.capacity { g.write } else { 0 };
        (0..g.len)
            .filter_map(|i| {
                g.slots[(start + i) % self.capacity]
                    .as_ref()
                    .map(|s| s.rewards[0])
            })
            .collect()
    }
}

// ---------------------------------------------------------------------------

fn seq(tag: f32) -> Sequence {
    Sequence {
        obs: vec![tag; 16],
        actions: vec![0; 4],
        rewards: vec![tag; 4],
        discounts: vec![0.9; 4],
        h0: vec![0.0; 4],
        c0: vec![0.0; 4],
        actor_id: 0,
        valid_len: 4,
    }
}

/// Drive the seed replica and the sharded buffer at `shards = 1`
/// through an identical randomized add/sample/update workload (the
/// learner's pattern: each update follows its sample immediately) and
/// assert bit-for-bit agreement at every step.
#[test]
fn shards_1_reproduces_seed_replay_bit_for_bit() {
    let capacity = 64usize;
    let (alpha, min_priority) = (0.9, 1e-3);
    let golden = SeedReplay::new(capacity, alpha, min_priority);
    let sharded = SequenceReplay::new(ReplayConfig {
        capacity,
        alpha,
        min_priority,
        shards: 1,
    });

    let mut ops = Pcg32::seeded(42);
    // Identical sampling RNG streams on both sides.
    let mut rng_a = Pcg32::seeded(7);
    let mut rng_b = Pcg32::seeded(7);
    let mut tag = 0f32;
    let mut samples = 0u32;
    for step in 0..2_000 {
        if ops.next_f64() < 0.7 || golden.len() < 8 {
            golden.add(seq(tag));
            sharded.add(seq(tag));
            tag += 1.0;
        } else {
            let a = golden.sample(8, &mut rng_a).expect("golden sample");
            let b = sharded.sample(8, &mut rng_b).expect("sharded sample");
            assert_eq!(a.slots, b.slots, "slots diverged at step {step}");
            for (x, y) in a.sequences.iter().zip(&b.sequences) {
                assert_eq!(x.rewards, y.rewards, "payload diverged at {step}");
            }
            // Immediate write-back, the serialized learner's pattern
            // (every sampled generation still matches its slot).
            let prios: Vec<f32> =
                (0..8).map(|_| ops.next_f64() as f32 * 10.0).collect();
            golden.update_priorities(&a.slots, &prios);
            sharded.update_priorities(&b.slots, &b.generations, &prios);
            samples += 1;
        }
    }
    assert!(samples > 100, "workload degenerated: {samples} samples");
    assert_eq!(golden.len(), sharded.len());
    // Priorities agree exactly, slot by slot.
    for slot in 0..capacity {
        assert_eq!(
            golden.priority_of(slot),
            sharded.priority_of(slot),
            "priority diverged at slot {slot}"
        );
    }
    // Contents agree in insertion order.
    let tags: Vec<f32> = sharded
        .snapshot()
        .iter()
        .map(|s| s.rewards[0])
        .collect();
    assert_eq!(golden.snapshot_tags(), tags);
}

/// The batched-ingest acceptance: `insert_batch = 1` through the
/// `IngestQueue` must reproduce the seed's direct `add` stream
/// bit-for-bit — same slots, same generations, same snapshot, same
/// sampled batches — under the learner's interleaved workload.
#[test]
fn insert_batch_1_reproduces_direct_add_stream_bit_for_bit() {
    for shards in [1usize, 4] {
        let capacity = 64usize;
        let cfg = || ReplayConfig {
            capacity,
            alpha: 0.9,
            min_priority: 1e-3,
            shards,
        };
        let golden = Arc::new(SequenceReplay::new(cfg()));
        let queued = Arc::new(SequenceReplay::new(cfg()));
        let mut q = IngestQueue::new(queued.clone(), 1);
        let mut ops = Pcg32::seeded(44);
        let mut rng_a = Pcg32::seeded(11);
        let mut rng_b = Pcg32::seeded(11);
        let mut tag = 0f32;
        for step in 0..1_500 {
            if ops.next_f64() < 0.7 || golden.len() < 8 {
                golden.add(seq(tag));
                q.push(seq(tag));
                assert_eq!(q.pending(), 0, "insert_batch 1 must not buffer");
                tag += 1.0;
            } else {
                let a = golden.sample(8, &mut rng_a).expect("golden sample");
                let b = queued.sample(8, &mut rng_b).expect("queued sample");
                assert_eq!(a.slots, b.slots, "slots diverged at step {step}");
                assert_eq!(
                    a.generations, b.generations,
                    "generations diverged at step {step}"
                );
                let prios: Vec<f32> =
                    (0..8).map(|_| ops.next_f64() as f32 * 10.0).collect();
                golden.update_priorities(&a.slots, &a.generations, &prios);
                queued.update_priorities(&b.slots, &b.generations, &prios);
            }
        }
        assert_eq!(golden.len(), queued.len(), "shards={shards}");
        assert_eq!(golden.inserts(), queued.inserts(), "shards={shards}");
        let a: Vec<f32> =
            golden.snapshot().iter().map(|s| s.rewards[0]).collect();
        let b: Vec<f32> =
            queued.snapshot().iter().map(|s| s.rewards[0]).collect();
        assert_eq!(a, b, "shards={shards}");
        for slot in 0..capacity {
            assert_eq!(
                golden.priority_of(slot),
                queued.priority_of(slot),
                "priority diverged at slot {slot} (shards={shards})"
            );
        }
    }
}

/// A single producer's stream through any `insert_batch` size preserves
/// the global insert order (slots and snapshot identical to the
/// unbatched stream) — batching only defers visibility, it never
/// reorders. Lock traffic drops by the shard-grouping amortization.
#[test]
fn batched_ingest_preserves_single_producer_order_and_amortizes_locks() {
    let capacity = 64usize;
    let shards = 4usize;
    let cfg = || ReplayConfig {
        capacity,
        alpha: 0.9,
        min_priority: 1e-3,
        shards,
    };
    let direct = Arc::new(SequenceReplay::new(cfg()));
    for i in 0..150 {
        direct.add(seq(i as f32));
    }
    let direct_locks = direct.lock_acquisitions();
    for insert_batch in [8usize, 16] {
        let batched = Arc::new(SequenceReplay::new(cfg()));
        let mut q = IngestQueue::new(batched.clone(), insert_batch);
        for i in 0..150 {
            q.push(seq(i as f32));
        }
        q.flush();
        let batched_locks = batched.lock_acquisitions();
        assert_eq!(direct.len(), batched.len());
        assert_eq!(direct.inserts(), batched.inserts());
        let a: Vec<f32> =
            direct.snapshot().iter().map(|s| s.rewards[0]).collect();
        let b: Vec<f32> =
            batched.snapshot().iter().map(|s| s.rewards[0]).collect();
        assert_eq!(a, b, "insert_batch={insert_batch}");
        // Identical contents -> identical sampling behavior.
        let mut rng_a = Pcg32::seeded(3);
        let mut rng_b = Pcg32::seeded(3);
        let sa = direct.sample(8, &mut rng_a).unwrap();
        let sb = batched.sample(8, &mut rng_b).unwrap();
        assert_eq!(sa.slots, sb.slots);
        assert_eq!(sa.generations, sb.generations);
        // 150 sequences over 4 shards: ceil(150/k) flushes of at most
        // min(k, 4) locks each (k > shard count, so each flush
        // amortizes) — strictly fewer acquisitions than the 150 the
        // unbatched stream pays.
        assert!(
            batched_locks < direct_locks,
            "insert_batch={insert_batch}: {batched_locks} locks >= \
             {direct_locks}"
        );
    }
}

/// Sanity for the sharded fast path itself: the same workload on
/// `shards = 4` keeps the ring semantics (len, insertion order) even
/// though slot ids and RNG consumption legitimately differ.
#[test]
fn sharded_workload_preserves_ring_semantics() {
    let sharded = SequenceReplay::new(ReplayConfig {
        capacity: 64,
        alpha: 0.9,
        min_priority: 1e-3,
        shards: 4,
    });
    let mut ops = Pcg32::seeded(43);
    let mut rng = Pcg32::seeded(9);
    let mut tag = 0f32;
    for _ in 0..2_000 {
        if ops.next_f64() < 0.7 || sharded.len() < 8 {
            sharded.add(seq(tag));
            tag += 1.0;
        } else {
            let b = sharded.sample(8, &mut rng).expect("sample");
            let prios: Vec<f32> =
                (0..8).map(|_| ops.next_f64() as f32 * 10.0).collect();
            sharded.update_priorities(&b.slots, &b.generations, &prios);
        }
    }
    assert_eq!(sharded.len(), 64);
    let tags: Vec<f32> = sharded.snapshot().iter().map(|s| s.rewards[0]).collect();
    // Insertion order: the newest 64 tags, ascending.
    let newest: Vec<f32> = ((tag as usize - 64)..tag as usize)
        .map(|t| t as f32)
        .collect();
    assert_eq!(tags, newest);
}
