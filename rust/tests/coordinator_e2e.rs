//! End-to-end integration: the full SEED dataflow against the real PJRT
//! backend (artifacts required; skipped otherwise) and failure-injection
//! checks against the mock.

use rlarch::config::{InferenceMode, SystemConfig};
use rlarch::coordinator;
use rlarch::metrics::Registry;
use rlarch::runtime::{Backend, MockModel, ModelDims, XlaServer};
use std::path::{Path, PathBuf};
use std::sync::Arc;

fn artifacts_dir() -> Option<PathBuf> {
    let dir = Path::new(env!("CARGO_MANIFEST_DIR")).join("artifacts");
    dir.join("manifest.json").exists().then_some(dir)
}

fn small_cfg() -> SystemConfig {
    let mut cfg = SystemConfig::default();
    cfg.env.name = "catch".into();
    cfg.env.sticky_action_prob = 0.0;
    cfg.actors.num_actors = 3;
    cfg.learner.max_steps = 12;
    cfg.learner.min_replay = 20;
    cfg.learner.target_update_interval = 5;
    cfg
}

#[test]
#[ignore = "requires AOT artifacts (make artifacts) and a PJRT-enabled xla crate; the vendored host-only shim cannot execute HLO"]
fn seed_central_e2e_on_real_artifacts() {
    let Some(dir) = artifacts_dir() else {
        eprintln!("skipping: run `make artifacts` first");
        return;
    };
    let cfg = small_cfg();
    let (_server, handle) = XlaServer::spawn(&dir, None, true).unwrap();
    let report =
        coordinator::run(&cfg, Backend::Xla(handle), Registry::new()).unwrap();
    assert_eq!(report.learner.steps, 12);
    assert!(report.learner.final_loss.is_finite());
    assert!(report.env_steps > 100);
    assert!(report.episodes > 0);
    assert!(report.inference_batches > 0);
    assert!(report.learner.target_syncs >= 2);
}

#[test]
#[ignore = "requires AOT artifacts (make artifacts) and a PJRT-enabled xla crate; the vendored host-only shim cannot execute HLO"]
fn local_mode_e2e_on_real_artifacts() {
    let Some(dir) = artifacts_dir() else {
        eprintln!("skipping: run `make artifacts` first");
        return;
    };
    let mut cfg = small_cfg();
    cfg.mode = InferenceMode::Local;
    cfg.actors.num_actors = 2;
    cfg.learner.max_steps = 8;
    let (_server, handle) = XlaServer::spawn(&dir, None, true).unwrap();
    let report =
        coordinator::run(&cfg, Backend::Xla(handle), Registry::new()).unwrap();
    assert_eq!(report.learner.steps, 8);
    assert_eq!(report.inference_batches, 0); // no batcher in local mode
}

#[test]
fn metrics_are_consistent_with_report() {
    // Mock backend: verify conservation between metrics and RunReport.
    let mut cfg = small_cfg();
    cfg.learner.max_steps = 20;
    let dims = ModelDims {
        obs_len: 400,
        hidden: 16,
        num_actions: 4,
        seq_len: cfg.learner.seq_len(),
        train_batch: cfg.learner.train_batch,
    };
    let metrics = Registry::new();
    let report = coordinator::run(
        &cfg,
        Backend::Mock(Arc::new(MockModel::new(dims, 4))),
        metrics.clone(),
    )
    .unwrap();
    let snap = metrics.snapshot();
    assert_eq!(snap["actor.env_steps"] as u64, report.env_steps);
    assert_eq!(snap["learner.steps"] as u64, report.learner.steps);
    // Every batched item belongs to some actor request.
    assert_eq!(snap["batcher.items"] as u64 > 0, true);
    assert!(snap["batcher.items"] <= snap["actor.env_steps"] + 1.0);
}

#[test]
fn degenerate_configs_still_terminate() {
    // 1 actor, batch window tiny, learner wants more data than one actor
    // produces quickly: must still converge and shut down.
    let mut cfg = small_cfg();
    cfg.actors.num_actors = 1;
    cfg.batcher.timeout_us = 1;
    cfg.learner.max_steps = 3;
    cfg.learner.min_replay = 16;
    let dims = ModelDims {
        obs_len: 400,
        hidden: 8,
        num_actions: 4,
        seq_len: cfg.learner.seq_len(),
        train_batch: cfg.learner.train_batch,
    };
    let report = coordinator::run(
        &cfg,
        Backend::Mock(Arc::new(MockModel::new(dims, 5))),
        Registry::new(),
    )
    .unwrap();
    assert_eq!(report.learner.steps, 3);
}

#[test]
fn vecenv_actors_raise_batch_occupancy_over_single_env_actors() {
    // The tentpole acceptance check: 2 actor threads driving 8 envs each
    // must reach higher mean inference-batch occupancy than 2 classic
    // single-env actors — more environments in flight behind the same
    // thread count.
    let run_with = |envs_per_actor: usize| {
        let mut cfg = small_cfg();
        cfg.actors.num_actors = 2;
        cfg.actors.envs_per_actor = envs_per_actor;
        cfg.learner.max_steps = 25;
        cfg.learner.min_replay = 16;
        cfg.batcher.max_batch = 16;
        cfg.batcher.batch_sizes = vec![1, 16];
        cfg.batcher.timeout_us = 1_000;
        let dims = ModelDims {
            obs_len: 400,
            hidden: 16,
            num_actions: 4,
            seq_len: cfg.learner.seq_len(),
            train_batch: cfg.learner.train_batch,
        };
        coordinator::run(
            &cfg,
            Backend::Mock(Arc::new(MockModel::new(dims, 9))),
            Registry::new(),
        )
        .unwrap()
    };
    let single = run_with(1);
    let vec8 = run_with(8);
    assert_eq!(single.total_envs, 2);
    assert_eq!(vec8.total_envs, 16);
    assert!(single.inference_batches > 0 && vec8.inference_batches > 0);
    assert!(
        vec8.mean_batch_occupancy > single.mean_batch_occupancy,
        "vecenv occupancy {} <= single-env occupancy {}",
        vec8.mean_batch_occupancy,
        single.mean_batch_occupancy
    );
    // 2 threads x 8 envs submit 16 rows per cycle: real batches, not
    // singletons.
    assert!(
        vec8.mean_batch_occupancy >= 4.0,
        "vecenv occupancy only {}",
        vec8.mean_batch_occupancy
    );
}

#[test]
fn all_registered_envs_run_e2e_with_mock() {
    for env in rlarch::env::registered_envs() {
        let mut cfg = small_cfg();
        cfg.env.name = env.to_string();
        cfg.learner.max_steps = 5;
        let dims = ModelDims {
            obs_len: 400,
            hidden: 8,
            num_actions: 4,
            seq_len: cfg.learner.seq_len(),
            train_batch: cfg.learner.train_batch,
        };
        let report = coordinator::run(
            &cfg,
            Backend::Mock(Arc::new(MockModel::new(dims, 6))),
            Registry::new(),
        )
        .unwrap();
        assert_eq!(report.learner.steps, 5, "env {env}");
        assert!(report.env_steps > 0, "env {env}");
    }
}
