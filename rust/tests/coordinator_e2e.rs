//! End-to-end integration: the full SEED dataflow against the real PJRT
//! backend (artifacts required; skipped otherwise) and failure-injection
//! checks against the mock.

use rlarch::config::{InferenceMode, LearnerConfig, SystemConfig};
use rlarch::coordinator;
use rlarch::coordinator::actor::{run_actor, ActorArgs};
use rlarch::coordinator::learner::{run_learner, LearnerArgs};
use rlarch::coordinator::{assemble_batch, Batcher, LearnerStats};
use rlarch::exec::ShutdownToken;
use rlarch::metrics::Registry;
use rlarch::policy::{CentralClient, LocalClient, PolicyClient};
use rlarch::replay::{ReplayConfig, SequenceReplay};
use rlarch::rl::{actor_epsilon, epsilon_greedy, Sequence, SequenceBuilder, Transition};
use rlarch::runtime::{Backend, InferRequest, MockModel, ModelDims, XlaServer};
use rlarch::util::prng::Pcg32;
use rlarch::vecenv::VecEnv;
use std::path::{Path, PathBuf};
use std::sync::{Arc, Mutex};

fn artifacts_dir() -> Option<PathBuf> {
    let dir = Path::new(env!("CARGO_MANIFEST_DIR")).join("artifacts");
    dir.join("manifest.json").exists().then_some(dir)
}

fn small_cfg() -> SystemConfig {
    let mut cfg = SystemConfig::default();
    cfg.env.name = "catch".into();
    cfg.env.sticky_action_prob = 0.0;
    cfg.actors.num_actors = 3;
    cfg.learner.max_steps = 12;
    cfg.learner.min_replay = 20;
    cfg.learner.target_update_interval = 5;
    cfg
}

#[test]
#[ignore = "requires AOT artifacts (make artifacts) and a PJRT-enabled xla crate; the vendored host-only shim cannot execute HLO"]
fn seed_central_e2e_on_real_artifacts() {
    let Some(dir) = artifacts_dir() else {
        eprintln!("skipping: run `make artifacts` first");
        return;
    };
    let cfg = small_cfg();
    let (_server, handle) = XlaServer::spawn(&dir, None, true).unwrap();
    let report =
        coordinator::run(&cfg, Backend::Xla(handle), Registry::new()).unwrap();
    assert_eq!(report.learner.steps, 12);
    assert!(report.learner.final_loss.is_finite());
    assert!(report.env_steps > 100);
    assert!(report.episodes > 0);
    assert!(report.inference_batches > 0);
    assert!(report.learner.target_syncs >= 2);
}

#[test]
#[ignore = "requires AOT artifacts (make artifacts) and a PJRT-enabled xla crate; the vendored host-only shim cannot execute HLO"]
fn local_mode_e2e_on_real_artifacts() {
    let Some(dir) = artifacts_dir() else {
        eprintln!("skipping: run `make artifacts` first");
        return;
    };
    let mut cfg = small_cfg();
    cfg.mode = InferenceMode::Local;
    cfg.actors.num_actors = 2;
    cfg.learner.max_steps = 8;
    let (_server, handle) = XlaServer::spawn(&dir, None, true).unwrap();
    let report =
        coordinator::run(&cfg, Backend::Xla(handle), Registry::new()).unwrap();
    assert_eq!(report.learner.steps, 8);
    assert_eq!(report.inference_batches, 0); // no batcher in local mode
}

#[test]
fn metrics_are_consistent_with_report() {
    // Mock backend: verify conservation between metrics and RunReport.
    let mut cfg = small_cfg();
    cfg.learner.max_steps = 20;
    let dims = ModelDims {
        obs_len: 400,
        hidden: 16,
        num_actions: 4,
        seq_len: cfg.learner.seq_len(),
        train_batch: cfg.learner.train_batch,
    };
    let metrics = Registry::new();
    let report = coordinator::run(
        &cfg,
        Backend::Mock(Arc::new(MockModel::new(dims, 4))),
        metrics.clone(),
    )
    .unwrap();
    let snap = metrics.snapshot();
    assert_eq!(snap["actor.env_steps"] as u64, report.env_steps);
    assert_eq!(snap["learner.steps"] as u64, report.learner.steps);
    // Every batched row belongs to some actor submission; the pipelined
    // loop keeps up to one submission per env slot in flight at
    // shutdown, so rows may lead recorded steps by at most total_envs.
    assert_eq!(snap["batcher.items"] as u64 > 0, true);
    assert!(
        snap["batcher.items"]
            <= snap["actor.env_steps"] + report.total_envs as f64
    );
    // The default config runs the pooled transition path: the pool
    // effectiveness gauge is published and sane.
    let hit_rate = snap["actor.pool_hit_rate"];
    assert!((0.0..=1.0).contains(&hit_rate), "pool hit rate {hit_rate}");
    // Batched-ingest accounting is published even at insert_batch = 1.
    assert!(snap["replay.lock_acquisitions"] > 0.0);
}

#[test]
fn degenerate_configs_still_terminate() {
    // 1 actor, batch window tiny, learner wants more data than one actor
    // produces quickly: must still converge and shut down.
    let mut cfg = small_cfg();
    cfg.actors.num_actors = 1;
    cfg.batcher.timeout_us = 1;
    cfg.learner.max_steps = 3;
    cfg.learner.min_replay = 16;
    let dims = ModelDims {
        obs_len: 400,
        hidden: 8,
        num_actions: 4,
        seq_len: cfg.learner.seq_len(),
        train_batch: cfg.learner.train_batch,
    };
    let report = coordinator::run(
        &cfg,
        Backend::Mock(Arc::new(MockModel::new(dims, 5))),
        Registry::new(),
    )
    .unwrap();
    assert_eq!(report.learner.steps, 3);
}

#[test]
fn vecenv_actors_raise_batch_occupancy_over_single_env_actors() {
    // The tentpole acceptance check: 2 actor threads driving 8 envs each
    // must reach higher mean inference-batch occupancy than 2 classic
    // single-env actors — more environments in flight behind the same
    // thread count.
    let run_with = |envs_per_actor: usize| {
        let mut cfg = small_cfg();
        cfg.actors.num_actors = 2;
        cfg.actors.envs_per_actor = envs_per_actor;
        cfg.learner.max_steps = 25;
        cfg.learner.min_replay = 16;
        cfg.batcher.max_batch = 16;
        cfg.batcher.batch_sizes = vec![1, 16];
        cfg.batcher.timeout_us = 1_000;
        let dims = ModelDims {
            obs_len: 400,
            hidden: 16,
            num_actions: 4,
            seq_len: cfg.learner.seq_len(),
            train_batch: cfg.learner.train_batch,
        };
        coordinator::run(
            &cfg,
            Backend::Mock(Arc::new(MockModel::new(dims, 9))),
            Registry::new(),
        )
        .unwrap()
    };
    let single = run_with(1);
    let vec8 = run_with(8);
    assert_eq!(single.total_envs, 2);
    assert_eq!(vec8.total_envs, 16);
    assert!(single.inference_batches > 0 && vec8.inference_batches > 0);
    assert!(
        vec8.mean_batch_occupancy > single.mean_batch_occupancy,
        "vecenv occupancy {} <= single-env occupancy {}",
        vec8.mean_batch_occupancy,
        single.mean_batch_occupancy
    );
    // 2 threads x 8 envs submit 16 rows per cycle: real batches, not
    // singletons.
    assert!(
        vec8.mean_batch_occupancy >= 4.0,
        "vecenv occupancy only {}",
        vec8.mean_batch_occupancy
    );
}

// ---------------------------------------------------------------------------
// Policy-layer pipeline: equivalence + overlap acceptance
// ---------------------------------------------------------------------------

/// Config for the deterministic actor-equivalence runs: 3 env slots on
/// one thread, a batch cap *below* E (forces multi-row submissions to
/// split), no artificial step cost.
fn equivalence_cfg() -> (SystemConfig, ModelDims) {
    let mut cfg = SystemConfig::default();
    cfg.env.name = "catch".into();
    cfg.env.step_cost_us = 0;
    cfg.env.frame_stack = 4;
    cfg.actors.num_actors = 1;
    cfg.actors.envs_per_actor = 3;
    cfg.learner.burn_in = 2;
    cfg.learner.unroll_len = 4;
    cfg.learner.seq_overlap = 2;
    cfg.batcher.max_batch = 2;
    cfg.batcher.batch_sizes = vec![1, 2];
    cfg.batcher.timeout_us = 200;
    let dims = ModelDims {
        obs_len: 400,
        hidden: 8,
        num_actions: 4,
        seq_len: 6,
        train_batch: 2,
    };
    (cfg, dims)
}

/// The seed's serialized actor loop, replicated verbatim as the golden
/// reference: blocking chunked inference at the top of every round, a
/// full-slab obs clone before stepping, per-row reply copies. The
/// policy-layer actor at `pipeline_depth = 1` must reproduce its replay
/// contents bit-for-bit.
fn reference_seed_loop(
    cfg: &SystemConfig,
    dims: ModelDims,
    backend: &Backend,
    rounds: u64,
    replay: &SequenceReplay,
) -> (u64, u64) {
    let id = 0usize;
    let e = cfg.actors.envs_per_actor.max(1);
    let total_slots = cfg.actors.num_actors * e;
    let mut venv = VecEnv::from_config(&cfg.env, e, (id * e) as u64 + 1).unwrap();
    let epsilons: Vec<f64> = (0..e)
        .map(|s| {
            actor_epsilon(
                id * e + s,
                total_slots,
                cfg.actors.epsilon_base,
                cfg.actors.epsilon_alpha,
            )
        })
        .collect();
    let mut rngs: Vec<Pcg32> = (0..e)
        .map(|s| Pcg32::seeded(cfg.seed ^ (0xAC70 + (id * e + s) as u64)))
        .collect();
    let mut builders: Vec<SequenceBuilder> = (0..e)
        .map(|s| {
            SequenceBuilder::new(
                cfg.learner.seq_len(),
                cfg.learner.seq_overlap,
                dims.obs_len,
                dims.hidden,
                id * e + s,
            )
        })
        .collect();
    let (ol, hd, na) = (dims.obs_len, dims.hidden, dims.num_actions);
    let mut obs = venv.new_obs_batch();
    let mut h = vec![0.0f32; e * hd];
    let mut c = vec![0.0f32; e * hd];
    venv.reset_all(&mut obs);
    let mut actions = vec![0usize; e];
    let cap = cfg.batcher.max_batch.max(1);

    for _ in 0..rounds {
        let mut q = vec![0.0f32; e * na];
        let mut h_next = vec![0.0f32; e * hd];
        let mut c_next = vec![0.0f32; e * hd];
        let mut start = 0usize;
        while start < e {
            let n = cap.min(e - start);
            let r = backend
                .infer(InferRequest {
                    n,
                    h: h[start * hd..(start + n) * hd].to_vec(),
                    c: c[start * hd..(start + n) * hd].to_vec(),
                    obs: obs[start * ol..(start + n) * ol].to_vec(),
                })
                .unwrap();
            q[start * na..(start + n) * na].copy_from_slice(&r.q);
            h_next[start * hd..(start + n) * hd].copy_from_slice(&r.h);
            c_next[start * hd..(start + n) * hd].copy_from_slice(&r.c);
            start += n;
        }
        for s in 0..e {
            actions[s] = epsilon_greedy(
                &q[s * na..(s + 1) * na],
                epsilons[s],
                &mut rngs[s],
            );
        }
        let prev_obs = obs.clone();
        let step_results: Vec<rlarch::env::Step> =
            venv.step_all(&actions, &mut obs).to_vec();
        for s in 0..e {
            let step = &step_results[s];
            let discount = if step.done && !step.truncated {
                0.0
            } else {
                cfg.learner.gamma as f32
            };
            if let Some(seq) = builders[s].push(Transition {
                obs: prev_obs[s * ol..(s + 1) * ol].to_vec(),
                action: actions[s] as i32,
                reward: step.reward,
                discount,
                h: h[s * hd..(s + 1) * hd].to_vec(),
                c: c[s * hd..(s + 1) * hd].to_vec(),
            }) {
                replay.add(seq);
            }
            if step.done {
                h[s * hd..(s + 1) * hd].fill(0.0);
                c[s * hd..(s + 1) * hd].fill(0.0);
            } else {
                h[s * hd..(s + 1) * hd]
                    .copy_from_slice(&h_next[s * hd..(s + 1) * hd]);
                c[s * hd..(s + 1) * hd]
                    .copy_from_slice(&c_next[s * hd..(s + 1) * hd]);
            }
        }
    }
    for b in &mut builders {
        if let Some(seq) = b.flush() {
            replay.add(seq);
        }
    }
    (venv.total_steps(), venv.episodes_completed())
}

/// Run the policy-layer actor for a fixed round count and return its
/// stats + replay contents. `central` routes through a real batcher.
fn run_policy_actor(
    cfg: &SystemConfig,
    dims: ModelDims,
    backend: &Backend,
    rounds: u64,
    central: bool,
) -> (rlarch::coordinator::ActorStats, Vec<Arc<Sequence>>) {
    let replay = Arc::new(SequenceReplay::new(ReplayConfig {
        capacity: 4_096,
        ..Default::default()
    }));
    let metrics = Registry::new();
    let batcher = central
        .then(|| Batcher::spawn(cfg.batcher.clone(), backend.clone(), metrics.clone()));
    let policy: Box<dyn PolicyClient> = match &batcher {
        Some((_, handle)) => {
            Box::new(CentralClient::new(handle.clone(), 0, dims, &metrics))
        }
        None => Box::new(LocalClient::new(
            backend.clone(),
            cfg.batcher.max_batch,
            dims,
            &metrics,
        )),
    };
    let stats = run_actor(ActorArgs {
        id: 0,
        cfg: cfg.clone(),
        dims,
        policy,
        replay: replay.clone(),
        metrics,
        shutdown: ShutdownToken::new(),
        max_rounds: Some(rounds),
    })
    .unwrap();
    if let Some((b, handle)) = batcher {
        drop(handle);
        b.join();
    }
    (stats, replay.snapshot())
}

#[test]
fn pipeline_depth1_reproduces_serialized_actor_bit_for_bit() {
    // Acceptance: pipeline_depth = 1 must reproduce the seed's
    // serialized loop exactly — same RNG streams, same replay contents
    // — through BOTH policy paths (central batcher and local backend).
    let (cfg, dims) = equivalence_cfg();
    let rounds = 60u64;
    let backend = Backend::Mock(Arc::new(MockModel::new(dims, 11)));

    let golden = Arc::new(SequenceReplay::new(ReplayConfig {
        capacity: 4_096,
        ..Default::default()
    }));
    let (ref_steps, ref_episodes) =
        reference_seed_loop(&cfg, dims, &backend, rounds, &golden);
    let golden = golden.snapshot();
    assert!(!golden.is_empty(), "reference produced no sequences");

    for central in [true, false] {
        let (stats, seqs) = run_policy_actor(&cfg, dims, &backend, rounds, central);
        assert_eq!(stats.env_steps, ref_steps, "central={central}");
        assert_eq!(stats.episodes, ref_episodes, "central={central}");
        assert_eq!(
            seqs.len(),
            golden.len(),
            "sequence count diverged (central={central})"
        );
        for (i, (a, b)) in seqs.iter().zip(&golden).enumerate() {
            assert_eq!(a, b, "sequence {i} diverged (central={central})");
        }
    }
}

#[test]
fn pooled_batched_ingest_preserves_the_actor_replay_stream() {
    // Acceptance (ISSUE 4): with the recycling pool attached and any
    // single-actor insert_batch, the actor -> replay stream must be
    // value-identical to the seed path — pooling only recycles buffers,
    // batching only defers visibility; neither may change the emitted
    // sequences or their order. A small ring forces evictions so the
    // pool's recycle loop (evict -> release -> acquire) actually runs.
    // Sampled-batch equality for identical buffer contents is pinned in
    // tests/replay_equivalence.rs.
    let (cfg, dims) = equivalence_cfg();
    let rounds = 60u64;
    let backend = Backend::Mock(Arc::new(MockModel::new(dims, 11)));
    // Golden: the unpooled, unbatched policy actor (itself pinned to
    // the verbatim seed loop by the equivalence test above).
    let (golden_stats, golden) =
        run_policy_actor(&cfg, dims, &backend, rounds, false);
    assert!(golden.len() > 32, "workload too small to wrap the test ring");

    for insert_batch in [1usize, 4] {
        let mut cfg = cfg.clone();
        cfg.replay.insert_batch = insert_batch;
        let pool = Arc::new(rlarch::rl::SequencePool::new());
        let replay = Arc::new(
            SequenceReplay::new(ReplayConfig {
                capacity: 32,
                shards: 2,
                ..Default::default()
            })
            .with_pool(pool.clone()),
        );
        let metrics = Registry::new();
        let policy: Box<dyn PolicyClient> = Box::new(LocalClient::new(
            backend.clone(),
            cfg.batcher.max_batch,
            dims,
            &metrics,
        ));
        let stats = run_actor(ActorArgs {
            id: 0,
            cfg: cfg.clone(),
            dims,
            policy,
            replay: replay.clone(),
            metrics: metrics.clone(),
            shutdown: ShutdownToken::new(),
            max_rounds: Some(rounds),
        })
        .unwrap();
        assert_eq!(stats.env_steps, golden_stats.env_steps);
        assert_eq!(stats.episodes, golden_stats.episodes);
        // The wrapped ring holds the newest 32 sequences: they must be
        // byte-identical to the golden stream's tail.
        let seqs = replay.snapshot();
        assert_eq!(seqs.len(), 32, "insert_batch={insert_batch}");
        let tail = &golden[golden.len() - 32..];
        for (i, (a, b)) in seqs.iter().zip(tail).enumerate() {
            assert_eq!(
                a, b,
                "sequence {i} diverged (insert_batch={insert_batch})"
            );
        }
        // The ring wrapped, so evictions recycled buffers and later
        // emits drew them from the pool.
        assert!(
            pool.hits() > 0,
            "pool never recycled (insert_batch={insert_batch})"
        );
        let rate = metrics.gauge("actor.pool_hit_rate").get();
        assert!((0.0..=1.0).contains(&rate), "hit rate {rate}");
    }
}

#[test]
fn pipeline_depth2_preserves_per_slot_trajectories() {
    // Pipelining reorders work *across* slot groups, never within a
    // slot: each slot's trajectory (and its sliced sequences, in order)
    // must be identical to the serialized run's.
    let (mut cfg, dims) = equivalence_cfg();
    cfg.actors.envs_per_actor = 4;
    let rounds = 60u64;
    let backend = Backend::Mock(Arc::new(MockModel::new(dims, 11)));
    let (s1, seqs1) = run_policy_actor(&cfg, dims, &backend, rounds, true);
    cfg.actors.pipeline_depth = 2;
    let (s2, seqs2) = run_policy_actor(&cfg, dims, &backend, rounds, true);
    assert_eq!(s1.env_steps, s2.env_steps);
    assert_eq!(s1.episodes, s2.episodes);
    let by_slot = |seqs: &[Arc<Sequence>]| {
        let mut m: std::collections::BTreeMap<usize, Vec<Arc<Sequence>>> =
            std::collections::BTreeMap::new();
        for s in seqs {
            m.entry(s.actor_id).or_default().push(s.clone());
        }
        m
    };
    assert_eq!(by_slot(&seqs1), by_slot(&seqs2));
}

#[test]
fn pipeline_depth2_beats_depth1_under_inference_latency() {
    // Acceptance: with injected inference latency, depth 2 must reach
    // strictly higher env-steps/sec than depth 1 at the same actor
    // count — the env CPU work of one slot group hides under the other
    // group's in-flight round-trip.
    // Structural expectation with W = 8 * 500us of env CPU per round and
    // L = 1.5ms of injected per-call GPU latency: depth 1 serializes
    // W + L ≈ 5.5ms/round; depth 2 runs two 1.5ms calls under the 4ms of
    // env work, ≈ max(W, 2L) + W/2 envelope ≈ 4.2ms/round (~1.3x). Only
    // strict ordering is asserted so CI scheduling noise (which slows
    // both runs alike) cannot flip the verdict.
    let run_with = |depth: usize| {
        let mut cfg = SystemConfig::default();
        cfg.env.name = "catch".into();
        cfg.env.step_cost_us = 500; // ALE-class env weight: real CPU work
        cfg.actors.num_actors = 1;
        cfg.actors.envs_per_actor = 8;
        cfg.actors.pipeline_depth = depth;
        cfg.learner.burn_in = 2;
        cfg.learner.unroll_len = 4;
        cfg.learner.seq_overlap = 2;
        cfg.batcher.max_batch = 8;
        cfg.batcher.batch_sizes = vec![1, 8];
        cfg.batcher.timeout_us = 100;
        let dims = ModelDims {
            obs_len: 400,
            hidden: 8,
            num_actions: 4,
            seq_len: 6,
            train_batch: 2,
        };
        let backend = Backend::Mock(Arc::new(
            MockModel::new(dims, 11)
                .with_infer_latency(std::time::Duration::from_micros(1_500)),
        ));
        let rounds = 40u64;
        let t0 = std::time::Instant::now();
        let (stats, _) = run_policy_actor(&cfg, dims, &backend, rounds, true);
        let elapsed = t0.elapsed().as_secs_f64();
        assert_eq!(stats.env_steps, rounds * 8);
        stats.env_steps as f64 / elapsed
    };
    let d1 = run_with(1);
    let d2 = run_with(2);
    assert!(
        d2 > d1,
        "pipelining should hide env work under inference: depth2 {d2:.0} \
         steps/s <= depth1 {d1:.0} steps/s"
    );
}

// ---------------------------------------------------------------------------
// Learner pipeline: seed-replica equivalence + prefetch overlap acceptance
// ---------------------------------------------------------------------------

fn learner_dims() -> ModelDims {
    ModelDims {
        obs_len: 8,
        hidden: 4,
        num_actions: 3,
        seq_len: 5,
        train_batch: 8,
    }
}

fn train_seq(d: &ModelDims, tag: f32) -> Sequence {
    Sequence {
        obs: vec![tag * 0.01; d.seq_len * d.obs_len],
        actions: vec![0; d.seq_len],
        rewards: vec![tag; d.seq_len],
        discounts: vec![0.9; d.seq_len],
        h0: vec![0.0; d.hidden],
        c0: vec![0.0; d.hidden],
        actor_id: 0,
        valid_len: d.seq_len,
    }
}

#[derive(Default)]
struct SeedLearnerOut {
    steps: u64,
    first_loss: f32,
    final_loss: f32,
    target_syncs: u64,
    loss_curve: Vec<(u64, f32)>,
    slots: Vec<Vec<usize>>,
}

/// The seed's serialized learner loop, replicated verbatim as the
/// golden reference: sample → assemble (fresh buffers) → train →
/// priority write-back, strictly in sequence. The split-phase learner
/// at `prefetch_depth = 1` must reproduce its sampled slots, loss
/// curve, and final replay priorities bit-for-bit.
fn reference_seed_learner(
    cfg: &LearnerConfig,
    dims: ModelDims,
    backend: &Backend,
    replay: &SequenceReplay,
    loss_every: u64,
    seed: u64,
) -> SeedLearnerOut {
    let mut rng = Pcg32::seeded(seed ^ 0x1EA8);
    let mut out = SeedLearnerOut::default();
    while out.steps < cfg.max_steps as u64 {
        let sampled = replay
            .sample(cfg.train_batch, &mut rng)
            .expect("replay is prefilled");
        let batch = assemble_batch(&sampled.sequences, &dims);
        let reply = backend.train(batch).unwrap();
        replay.update_priorities(
            &sampled.slots,
            &sampled.generations,
            &reply.priorities,
        );
        out.steps = reply.step;
        if out.first_loss == 0.0 {
            out.first_loss = reply.loss;
        }
        out.final_loss = reply.loss;
        if loss_every > 0 && out.steps % loss_every == 0 {
            out.loss_curve.push((out.steps, reply.loss));
        }
        if out.steps % cfg.target_update_interval as u64 == 0 {
            backend.sync_target().unwrap();
            out.target_syncs += 1;
        }
        out.slots.push(sampled.slots.clone());
    }
    out
}

/// Run the split-phase learner, recording each trained batch's sampled
/// slots through the probe. Returns (stats, slots, wall seconds).
fn run_learner_collecting(
    cfg: &LearnerConfig,
    dims: ModelDims,
    backend: &Backend,
    replay: &Arc<SequenceReplay>,
    loss_every: u64,
    seed: u64,
) -> (LearnerStats, Vec<Vec<usize>>, f64) {
    let recorded: Arc<Mutex<Vec<Vec<usize>>>> = Arc::new(Mutex::new(Vec::new()));
    let sink = recorded.clone();
    let t0 = std::time::Instant::now();
    let stats = run_learner(LearnerArgs {
        cfg: cfg.clone(),
        dims,
        backend: backend.clone(),
        replay: replay.clone(),
        metrics: Registry::new(),
        shutdown: ShutdownToken::new(),
        loss_every,
        seed,
        on_batch: Some(Box::new(move |slots: &[usize]| {
            sink.lock().unwrap().push(slots.to_vec());
        })),
    })
    .unwrap();
    let elapsed = t0.elapsed().as_secs_f64();
    let slots = recorded.lock().unwrap().clone();
    (stats, slots, elapsed)
}

#[test]
fn prefetch_depth1_reproduces_seed_learner_bit_for_bit() {
    // Acceptance: prefetch_depth = 1 must reproduce the seed learner
    // exactly — same RNG stream, same sampled slots, same loss curve,
    // same final replay priorities — against the verbatim replica.
    let d = learner_dims();
    let cfg = LearnerConfig {
        train_batch: 8,
        min_replay: 16,
        max_steps: 30,
        target_update_interval: 10,
        prefetch_depth: 1,
        ..Default::default()
    };
    let fresh_replay = || {
        let r = Arc::new(SequenceReplay::new(ReplayConfig {
            capacity: 64,
            ..Default::default()
        }));
        for i in 0..32 {
            r.add(train_seq(&d, (i % 7) as f32));
        }
        r
    };
    let golden_replay = fresh_replay();
    let live_replay = fresh_replay();
    let golden_backend = Backend::Mock(Arc::new(MockModel::new(d, 21)));
    let live_backend = Backend::Mock(Arc::new(MockModel::new(d, 21)));

    let golden =
        reference_seed_learner(&cfg, d, &golden_backend, &golden_replay, 10, 5);
    let (stats, slots, _) =
        run_learner_collecting(&cfg, d, &live_backend, &live_replay, 10, 5);

    assert_eq!(golden.steps, 30);
    assert_eq!(stats.steps, golden.steps);
    assert_eq!(slots, golden.slots, "sampled slot streams diverged");
    assert_eq!(stats.first_loss, golden.first_loss);
    assert_eq!(stats.final_loss, golden.final_loss);
    assert_eq!(stats.target_syncs, golden.target_syncs);
    assert_eq!(stats.loss_curve, golden.loss_curve);
    // The replay ends in the identical priority state, slot by slot.
    for slot in 0..64 {
        assert_eq!(
            golden_replay.priority_of(slot),
            live_replay.priority_of(slot),
            "priority diverged at slot {slot}"
        );
    }
}

#[test]
fn prefetch_depth2_beats_depth1_at_identical_sampled_batches() {
    // Acceptance: alpha = 0 freezes the sampling distribution (updates
    // keep every priority at 1.0), so depth 1 and depth 2 must train on
    // identical batch contents from the identical RNG stream — and with
    // injected mock train latency, depth 2 must be strictly faster:
    // the ~ms of per-step sample+assemble CPU hides under the 4 ms
    // accelerator step instead of extending the cycle. Only strict
    // ordering is asserted so CI scheduling noise (which slows both
    // runs alike) cannot flip the verdict.
    let d = ModelDims {
        obs_len: 800,
        hidden: 128,
        num_actions: 4,
        seq_len: 20,
        train_batch: 64,
    };
    let run_with = |depth: usize| {
        let cfg = LearnerConfig {
            train_batch: 64,
            min_replay: 64,
            max_steps: 25,
            target_update_interval: 1_000,
            prefetch_depth: depth,
            ..Default::default()
        };
        let replay = Arc::new(SequenceReplay::new(ReplayConfig {
            capacity: 128,
            alpha: 0.0,
            min_priority: 1e-3,
            shards: 1,
        }));
        for i in 0..128 {
            replay.add(train_seq(&d, (i % 11) as f32));
        }
        let backend = Backend::Mock(Arc::new(
            MockModel::new(d, 11)
                .with_train_latency(std::time::Duration::from_millis(4)),
        ));
        run_learner_collecting(&cfg, d, &backend, &replay, 0, 5)
    };
    let (s1, slots1, t1) = run_with(1);
    let (s2, slots2, t2) = run_with(2);
    assert_eq!(s1.steps, 25);
    assert_eq!(s2.steps, 25);
    assert_eq!(slots1, slots2, "sampled batch contents diverged");
    assert_eq!(s1.final_loss, s2.final_loss);
    assert!(
        t2 < t1,
        "prefetch should hide the CPU phases under the train step: \
         depth2 {t2:.3}s >= depth1 {t1:.3}s"
    );
}

#[test]
fn batch_native_defaults_off_and_off_means_the_per_slot_engine() {
    // PR 6 compatibility pin: `env.batch_native` must default to false,
    // and an explicit false must be indistinguishable from the implicit
    // default — same actor stats, same replay stream, through the full
    // central-batcher policy path.
    let (cfg, dims) = equivalence_cfg();
    assert!(
        !cfg.env.batch_native,
        "batch_native must default to the per-slot engine"
    );
    let rounds = 60u64;
    let backend = Backend::Mock(Arc::new(MockModel::new(dims, 11)));
    let (s_default, seqs_default) =
        run_policy_actor(&cfg, dims, &backend, rounds, true);
    let mut explicit = cfg.clone();
    explicit.env.batch_native = false;
    let (s_off, seqs_off) = run_policy_actor(&explicit, dims, &backend, rounds, true);
    assert_eq!(s_default.env_steps, s_off.env_steps);
    assert_eq!(s_default.episodes, s_off.episodes);
    assert_eq!(seqs_default, seqs_off);
}

#[test]
fn batch_native_actor_reproduces_per_slot_stream_bit_for_bit() {
    // Tentpole acceptance: the SoA engine behind `batch_native = true`
    // is a cost model, not a semantics change — the full policy-layer
    // actor must emit the identical replay stream, through both the
    // central batcher and the local client, at depth 1 and with
    // pipelined slot groups.
    let (base, dims) = equivalence_cfg();
    let rounds = 60u64;
    let backend = Backend::Mock(Arc::new(MockModel::new(dims, 11)));
    for (envs, depth) in [(3usize, 1usize), (4, 2)] {
        let mut cfg = base.clone();
        cfg.actors.envs_per_actor = envs;
        cfg.actors.pipeline_depth = depth;
        for central in [true, false] {
            let (s_slot, seqs_slot) =
                run_policy_actor(&cfg, dims, &backend, rounds, central);
            let mut soa = cfg.clone();
            soa.env.batch_native = true;
            let (s_soa, seqs_soa) =
                run_policy_actor(&soa, dims, &backend, rounds, central);
            let tag = format!("envs={envs} depth={depth} central={central}");
            assert_eq!(s_slot.env_steps, s_soa.env_steps, "{tag}");
            assert_eq!(s_slot.episodes, s_soa.episodes, "{tag}");
            assert_eq!(
                seqs_slot.len(),
                seqs_soa.len(),
                "sequence count diverged ({tag})"
            );
            for (i, (a, b)) in seqs_slot.iter().zip(&seqs_soa).enumerate() {
                assert_eq!(a, b, "sequence {i} diverged ({tag})");
            }
        }
    }
}

#[test]
fn batch_native_full_coordinator_run_terminates_on_every_env() {
    // E2E smoke: the SoA engine under the full multi-threaded
    // coordinator (actors + batcher + learner) for each registered env.
    for env in rlarch::env::registered_envs() {
        let mut cfg = small_cfg();
        cfg.env.name = env.to_string();
        cfg.env.batch_native = true;
        cfg.learner.max_steps = 5;
        let dims = ModelDims {
            obs_len: 400,
            hidden: 8,
            num_actions: 4,
            seq_len: cfg.learner.seq_len(),
            train_batch: cfg.learner.train_batch,
        };
        let report = coordinator::run(
            &cfg,
            Backend::Mock(Arc::new(MockModel::new(dims, 6))),
            Registry::new(),
        )
        .unwrap();
        assert_eq!(report.learner.steps, 5, "env {env}");
        assert!(report.env_steps > 0, "env {env}");
    }
}

#[test]
fn all_registered_envs_run_e2e_with_mock() {
    for env in rlarch::env::registered_envs() {
        let mut cfg = small_cfg();
        cfg.env.name = env.to_string();
        cfg.learner.max_steps = 5;
        let dims = ModelDims {
            obs_len: 400,
            hidden: 8,
            num_actions: 4,
            seq_len: cfg.learner.seq_len(),
            train_batch: cfg.learner.train_batch,
        };
        let report = coordinator::run(
            &cfg,
            Backend::Mock(Arc::new(MockModel::new(dims, 6))),
            Registry::new(),
        )
        .unwrap();
        assert_eq!(report.learner.steps, 5, "env {env}");
        assert!(report.env_steps > 0, "env {env}");
    }
}
