//! Calibration: the simulator, fed the REAL kernel traces extracted from
//! our R2D2 graphs (`make artifacts`), must reproduce the *shape* of the
//! paper's Figures 2-4. Absolute numbers differ (their testbed, their
//! TF build); who-wins/by-roughly-what-factor must hold. Bands below are
//! centered on the paper's reported values:
//!   Fig. 2: Math 57%, SM-util 15%, DRAM-BW 12% (rest latency/L2 ~16%)
//!   Fig. 3: 4->40 actors = 5.8x; 40->256 = 2x more
//!   Fig. 4: 80->40 SMs = 6% slowdown; 2 SMs = severe
//! Skipped when artifacts are absent.

use rlarch::simarch::{default_system, GpuModel, TraceSet};
use std::path::{Path, PathBuf};

fn artifacts() -> Option<PathBuf> {
    let dir = Path::new(env!("CARGO_MANIFEST_DIR")).join("artifacts");
    dir.join("kernel_trace.json").exists().then_some(dir)
}

macro_rules! require {
    () => {
        match artifacts() {
            Some(d) => TraceSet::load(&d).unwrap(),
            None => {
                eprintln!("skipping: run `make artifacts` first");
                return;
            }
        }
    };
}

fn system(ts: &TraceSet) -> rlarch::simarch::SystemModel {
    default_system(
        ts.find("infer_paper_scale").expect("infer trace").clone(),
        ts.find("train_paper_scale").expect("train trace").clone(),
    )
}

#[test]
#[ignore = "requires real kernel traces from `make artifacts` (the AOT pipeline is unavailable in the offline build)"]
fn fig2_breakdown_shape_on_real_trace() {
    let ts = require!();
    let gpu = GpuModel::new(rlarch::config::GpuModelConfig::default());
    let b = gpu.breakdown(ts.find("train_paper_scale").unwrap());
    let sum = b.math + b.sm_util + b.dram_bw + b.dram_latency + b.l2;
    assert!((sum - 1.0).abs() < 1e-9);
    // Math is the dominant component (paper: 57%).
    assert!(
        (0.40..=0.70).contains(&b.math),
        "math share {} outside band",
        b.math
    );
    // SM utilization is the second-largest (paper: 15%).
    assert!(
        (0.08..=0.35).contains(&b.sm_util),
        "sm_util share {}",
        b.sm_util
    );
    // DRAM bandwidth visible but not dominant (paper: 12%).
    assert!(
        (0.03..=0.20).contains(&b.dram_bw),
        "dram_bw share {}",
        b.dram_bw
    );
    // Paper's headline: < 2x total headroom from GPU uarch idealization.
    assert!(
        b.math > 0.5 - 0.15,
        "non-math headroom must stay under ~2x (math {})",
        b.math
    );
}

#[test]
#[ignore = "requires real kernel traces from `make artifacts` (the AOT pipeline is unavailable in the offline build)"]
fn fig3_actor_sweep_shape_on_real_trace() {
    let ts = require!();
    let m = system(&ts);
    let r4 = m.steady_state(4).env_rate;
    let r40 = m.steady_state(40).env_rate;
    let r256 = m.steady_state(256).env_rate;
    let up = r40 / r4;
    let beyond = r256 / r40;
    assert!((3.0..=12.0).contains(&up), "4->40 speedup {up} (paper 5.8)");
    assert!(
        (1.2..=4.0).contains(&beyond),
        "40->256 speedup {beyond} (paper 2.0)"
    );
    assert!(up > beyond, "knee at the HW-thread count must exist");
}

#[test]
#[ignore = "requires real kernel traces from `make artifacts` (the AOT pipeline is unavailable in the offline build)"]
fn fig3_power_story_on_real_trace() {
    let ts = require!();
    let m = system(&ts);
    let pts: Vec<_> = [4usize, 16, 40, 128, 256]
        .iter()
        .map(|&n| m.steady_state(n))
        .collect();
    // GPU power rises with actors; floor near idle (70 W).
    for w in pts.windows(2) {
        assert!(w[1].power_w >= w[0].power_w - 1e-9);
    }
    assert!(pts[0].power_w >= 70.0 && pts[0].power_w < 200.0);
    // Perf/W improves monotonically (paper's efficiency observation).
    for w in pts.windows(2) {
        assert!(
            w[1].perf_per_watt >= w[0].perf_per_watt * 0.999,
            "perf/W must not degrade: {} -> {}",
            w[0].perf_per_watt,
            w[1].perf_per_watt
        );
    }
}

#[test]
#[ignore = "requires real kernel traces from `make artifacts` (the AOT pipeline is unavailable in the offline build)"]
fn fig4_sm_sweep_shape_on_real_trace() {
    let ts = require!();
    let m = system(&ts);
    let base = m.steady_state(40).env_rate;
    let slow = |sms: usize| base / m.with_sms(sms).steady_state(40).env_rate;
    let s40 = slow(40);
    let s2 = slow(2);
    // Paper: halving SMs costs only ~6% (we allow up to 15%).
    assert!(s40 < 1.15, "80->40 SMs slowdown {s40} (paper 1.06)");
    // Monotone degradation, severe at 2 SMs.
    let mut prev = 1.0;
    for sms in [60, 40, 20, 10, 4, 2] {
        let s = slow(sms);
        assert!(s >= prev * 0.99, "non-monotone at {sms} SMs");
        prev = s;
    }
    assert!(s2 > 3.0, "2 SMs slowdown {s2} must be severe");
}

#[test]
#[ignore = "requires real kernel traces from `make artifacts` (the AOT pipeline is unavailable in the offline build)"]
fn cpu_gpu_ratio_conclusions() {
    let ts = require!();
    let m = system(&ts);
    // DGX-1 slice: 40 threads / 80 SMs = 1/2.
    assert!((m.cpu_gpu_ratio() - 0.5).abs() < 1e-12);
    // The paper's conclusion: ratio >= 1 wastes little GPU. Compare a
    // ratio-1 system (40 SMs) against baseline at a saturating actor
    // count: throughput within 15%, but energy per step improves because
    // SM power is gated.
    let n = 40;
    let base = m.steady_state(n);
    let ratio1 = m.with_sms(40).steady_state(n);
    assert!(ratio1.env_rate > 0.85 * base.env_rate);
    let energy_base = base.power_w / base.env_rate;
    let energy_r1 = ratio1.power_w / ratio1.env_rate;
    assert!(
        energy_r1 < energy_base,
        "ratio-1 system should cost less energy/step: {energy_r1} vs {energy_base}"
    );
}

#[test]
#[ignore = "requires real kernel traces from `make artifacts` (the AOT pipeline is unavailable in the offline build)"]
fn des_validates_analytic_on_real_trace() {
    let ts = require!();
    let m = system(&ts);
    for n in [8usize, 64] {
        let des = rlarch::simarch::des::simulate(&m, n, 0.4, 20e-6);
        let ana = m.steady_state(n);
        let ratio = des.env_rate / ana.env_rate;
        assert!(
            (0.5..2.0).contains(&ratio),
            "n={n}: DES {} vs analytic {} differ structurally",
            des.env_rate,
            ana.env_rate
        );
    }
}
