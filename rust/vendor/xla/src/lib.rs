//! Host-only shim of the `xla` PJRT bindings' API surface.
//!
//! The offline build environment has neither crates.io access nor a PJRT
//! plugin, so this vendored crate keeps `rlarch::runtime` compiling and
//! its host-side data paths working:
//!
//! * [`Literal`] is fully functional on the host (create from bytes, read
//!   shapes, read back typed data) — the `runtime::tensor` layer and its
//!   tests run for real.
//! * Everything that needs an actual PJRT runtime ([`PjRtClient::cpu`],
//!   compilation, execution) returns a descriptive [`Error`]. Callers
//!   already treat artifact execution as optional (tests skip, the CLI
//!   reports the error), so a stubbed runtime degrades gracefully.
//!
//! Swapping in real PJRT bindings is a Cargo.toml change; no rlarch code
//! references anything outside the genuine crate's API.

use std::fmt;
use std::path::Path;

/// Errors surfaced by the shim (and, in a real build, by PJRT).
#[derive(Debug)]
pub struct Error(String);

impl fmt::Display for Error {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        f.write_str(&self.0)
    }
}

impl std::error::Error for Error {}

impl Error {
    fn unsupported(what: &str) -> Self {
        Self(format!(
            "{what} is unavailable: rlarch was built against the vendored \
             host-only xla shim (no PJRT plugin in this environment)"
        ))
    }
}

pub type Result<T> = std::result::Result<T, Error>;

/// Element types our artifacts use.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum ElementType {
    F32,
    S32,
}

/// XLA primitive types (subset + catch-all for diagnostics).
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum PrimitiveType {
    F32,
    S32,
    Unsupported,
}

impl ElementType {
    fn primitive(self) -> PrimitiveType {
        match self {
            ElementType::F32 => PrimitiveType::F32,
            ElementType::S32 => PrimitiveType::S32,
        }
    }
}

/// Array shape: dims + primitive type.
#[derive(Clone, Debug)]
pub struct ArrayShape {
    dims: Vec<i64>,
    ty: PrimitiveType,
}

impl ArrayShape {
    pub fn dims(&self) -> &[i64] {
        &self.dims
    }

    pub fn primitive_type(&self) -> PrimitiveType {
        self.ty
    }
}

/// Native element types readable out of a [`Literal`].
pub trait NativeType: Copy {
    const PRIMITIVE: PrimitiveType;
    fn from_le(bytes: [u8; 4]) -> Self;
}

impl NativeType for f32 {
    const PRIMITIVE: PrimitiveType = PrimitiveType::F32;
    fn from_le(bytes: [u8; 4]) -> Self {
        f32::from_le_bytes(bytes)
    }
}

impl NativeType for i32 {
    const PRIMITIVE: PrimitiveType = PrimitiveType::S32;
    fn from_le(bytes: [u8; 4]) -> Self {
        i32::from_le_bytes(bytes)
    }
}

/// A host literal: shape + little-endian payload bytes.
#[derive(Clone, Debug)]
pub struct Literal {
    ty: ElementType,
    dims: Vec<i64>,
    bytes: Vec<u8>,
}

impl Literal {
    pub fn create_from_shape_and_untyped_data(
        ty: ElementType,
        dims: &[usize],
        data: &[u8],
    ) -> Result<Self> {
        let elems: usize = dims.iter().product();
        if data.len() != elems * 4 {
            return Err(Error(format!(
                "literal payload {} bytes != {} elements * 4",
                data.len(),
                elems
            )));
        }
        Ok(Self {
            ty,
            dims: dims.iter().map(|&d| d as i64).collect(),
            bytes: data.to_vec(),
        })
    }

    pub fn array_shape(&self) -> Result<ArrayShape> {
        Ok(ArrayShape {
            dims: self.dims.clone(),
            ty: self.ty.primitive(),
        })
    }

    pub fn to_vec<T: NativeType>(&self) -> Result<Vec<T>> {
        if self.ty.primitive() != T::PRIMITIVE {
            return Err(Error(format!(
                "literal is {:?}, asked for {:?}",
                self.ty.primitive(),
                T::PRIMITIVE
            )));
        }
        Ok(self
            .bytes
            .chunks_exact(4)
            .map(|b| T::from_le([b[0], b[1], b[2], b[3]]))
            .collect())
    }

    /// Destructure a tuple literal. Host-created literals are always
    /// arrays; tuples only come out of executable runs, which the shim
    /// cannot perform.
    pub fn to_tuple(self) -> Result<Vec<Literal>> {
        Err(Error::unsupported("Literal::to_tuple (tuple literals)"))
    }
}

/// Parsed HLO module (opaque; the shim only records the path).
pub struct HloModuleProto {
    _path: std::path::PathBuf,
}

impl HloModuleProto {
    pub fn from_text_file<P: AsRef<Path>>(path: P) -> Result<Self> {
        let path = path.as_ref();
        if !path.exists() {
            return Err(Error(format!("no such HLO file: {}", path.display())));
        }
        Err(Error::unsupported("HloModuleProto::from_text_file"))
    }
}

/// An XLA computation built from an HLO proto.
pub struct XlaComputation {
    _private: (),
}

impl XlaComputation {
    pub fn from_proto(_proto: &HloModuleProto) -> Self {
        Self { _private: () }
    }
}

/// A compiled executable (never constructible through the shim).
pub struct PjRtLoadedExecutable {
    _private: (),
}

impl PjRtLoadedExecutable {
    pub fn execute<L: std::borrow::Borrow<Literal>>(
        &self,
        _args: &[L],
    ) -> Result<Vec<Vec<PjRtBuffer>>> {
        Err(Error::unsupported("PjRtLoadedExecutable::execute"))
    }
}

/// A device buffer handle.
pub struct PjRtBuffer {
    _private: (),
}

impl PjRtBuffer {
    pub fn to_literal_sync(&self) -> Result<Literal> {
        Err(Error::unsupported("PjRtBuffer::to_literal_sync"))
    }
}

/// The PJRT client. The shim has no backing plugin, so construction
/// fails with a descriptive error and callers fall back / skip.
pub struct PjRtClient {
    _private: (),
}

impl PjRtClient {
    pub fn cpu() -> Result<Self> {
        Err(Error::unsupported("PjRtClient::cpu"))
    }

    pub fn compile(&self, _comp: &XlaComputation) -> Result<PjRtLoadedExecutable> {
        Err(Error::unsupported("PjRtClient::compile"))
    }

    pub fn platform_name(&self) -> String {
        "host-stub".to_string()
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn literal_roundtrip_f32() {
        let vals = [1.0f32, -2.5, 3.25];
        let bytes: Vec<u8> = vals.iter().flat_map(|v| v.to_le_bytes()).collect();
        let lit =
            Literal::create_from_shape_and_untyped_data(ElementType::F32, &[3], &bytes).unwrap();
        let shape = lit.array_shape().unwrap();
        assert_eq!(shape.dims(), &[3]);
        assert_eq!(shape.primitive_type(), PrimitiveType::F32);
        assert_eq!(lit.to_vec::<f32>().unwrap(), vals);
        assert!(lit.to_vec::<i32>().is_err());
    }

    #[test]
    fn payload_length_checked() {
        assert!(
            Literal::create_from_shape_and_untyped_data(ElementType::S32, &[2, 2], &[0u8; 15])
                .is_err()
        );
    }

    #[test]
    fn runtime_paths_error_cleanly() {
        assert!(PjRtClient::cpu().is_err());
        assert!(HloModuleProto::from_text_file("/nonexistent/x.hlo.txt").is_err());
    }
}
