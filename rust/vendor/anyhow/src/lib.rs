//! Minimal, dependency-free subset of the `anyhow` API.
//!
//! The offline build environment has no crates.io access, so this
//! vendored shim provides exactly the surface `rlarch` uses: an opaque
//! [`Error`] carrying a message (plus an optional source), the
//! [`Result`] alias, and the `anyhow!` / `bail!` / `ensure!` macros.
//! Like the real crate, `Error` deliberately does not implement
//! `std::error::Error` so the blanket `From` conversion below can exist.

use std::fmt;

/// An opaque error: a rendered message plus an optional source chain.
pub struct Error {
    msg: String,
    source: Option<Box<dyn std::error::Error + Send + Sync + 'static>>,
}

impl Error {
    /// Build an error from anything displayable.
    pub fn msg<M: fmt::Display>(message: M) -> Self {
        Self {
            msg: message.to_string(),
            source: None,
        }
    }

    /// The root cause chain, outermost first (for diagnostics).
    pub fn chain(&self) -> impl Iterator<Item = &(dyn std::error::Error + 'static)> + '_ {
        let mut next = self
            .source
            .as_deref()
            .map(|e| e as &(dyn std::error::Error + 'static));
        std::iter::from_fn(move || {
            let cur = next?;
            next = cur.source();
            Some(cur)
        })
    }
}

impl fmt::Display for Error {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        f.write_str(&self.msg)
    }
}

impl fmt::Debug for Error {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        write!(f, "{}", self.msg)?;
        for cause in self.chain() {
            write!(f, "\n\nCaused by:\n    {cause}")?;
        }
        Ok(())
    }
}

impl<E: std::error::Error + Send + Sync + 'static> From<E> for Error {
    fn from(e: E) -> Self {
        Self {
            msg: e.to_string(),
            source: Some(Box::new(e)),
        }
    }
}

/// `std::result::Result` defaulted to [`Error`].
pub type Result<T, E = Error> = std::result::Result<T, E>;

/// Construct an [`Error`] from a format string or a displayable value.
#[macro_export]
macro_rules! anyhow {
    ($msg:literal $(,)?) => {
        $crate::Error::msg(format!($msg))
    };
    ($err:expr $(,)?) => {
        $crate::Error::msg($err)
    };
    ($fmt:expr, $($arg:tt)*) => {
        $crate::Error::msg(format!($fmt, $($arg)*))
    };
}

/// Return early with an [`Error`].
#[macro_export]
macro_rules! bail {
    ($($arg:tt)*) => {
        return ::core::result::Result::Err($crate::anyhow!($($arg)*))
    };
}

/// Return early with an [`Error`] unless the condition holds.
#[macro_export]
macro_rules! ensure {
    ($cond:expr $(,)?) => {
        if !($cond) {
            $crate::bail!(concat!("condition failed: `", stringify!($cond), "`"));
        }
    };
    ($cond:expr, $($arg:tt)*) => {
        if !($cond) {
            $crate::bail!($($arg)*);
        }
    };
}

#[cfg(test)]
mod tests {
    use super::*;

    fn io_fail() -> Result<()> {
        Err(std::io::Error::new(std::io::ErrorKind::Other, "disk on fire"))?;
        Ok(())
    }

    #[test]
    fn question_mark_converts_std_errors() {
        let e = io_fail().unwrap_err();
        assert!(e.to_string().contains("disk on fire"));
        assert_eq!(e.chain().count(), 1);
    }

    #[test]
    fn macros_format_and_bail() {
        fn f(x: usize) -> Result<usize> {
            ensure!(x < 10, "x too big: {x}");
            if x == 3 {
                bail!("three is right out");
            }
            Ok(x)
        }
        assert_eq!(f(2).unwrap(), 2);
        assert!(f(12).unwrap_err().to_string().contains("12"));
        assert!(f(3).unwrap_err().to_string().contains("right out"));
        let e = anyhow!("plain {} message", 7);
        assert_eq!(e.to_string(), "plain 7 message");
    }
}
