//! Quickstart: end-to-end R2D2 training on the real three-layer stack.
//!
//! Loads the AOT artifacts (JAX/Pallas -> HLO text -> PJRT), spawns the
//! SEED coordinator (actor threads + central inference batcher + R2D2
//! learner), trains on Catch for a few hundred learner steps, logs the
//! loss curve, then evaluates the greedy policy and compares it against
//! a uniform-random baseline. This is the E2E validation run recorded in
//! EXPERIMENTS.md.
//!
//!     make artifacts && cargo run --release --example quickstart
//!
//! Flags: --steps N (default 300), --actors N (default 6), --env NAME.

use rlarch::cli::Cli;
use rlarch::config::SystemConfig;
use rlarch::coordinator;
use rlarch::env::wrappers::Wrapped;
use rlarch::metrics::Registry;
use rlarch::rl::argmax;
use rlarch::runtime::{Backend, InferRequest, XlaServer};
use rlarch::util::prng::Pcg32;
use std::path::Path;

fn eval_policy(
    backend: &Backend,
    cfg: &SystemConfig,
    episodes: usize,
    greedy: bool,
) -> anyhow::Result<f64> {
    let dims = backend.dims();
    let mut env = Wrapped::from_config(&cfg.env, 0xE7A1)?;
    let mut rng = Pcg32::seeded(7);
    let mut obs = vec![0.0f32; dims.obs_len];
    let mut h = vec![0.0f32; dims.hidden];
    let mut c = vec![0.0f32; dims.hidden];
    let mut total = 0.0f64;
    let mut done_eps = 0usize;
    env.reset(&mut obs);
    while done_eps < episodes {
        let action = if greedy {
            let r = backend.infer(InferRequest {
                n: 1,
                h: h.clone(),
                c: c.clone(),
                obs: obs.clone(),
            })?;
            h = r.h;
            c = r.c;
            argmax(&r.q)
        } else {
            rng.index(dims.num_actions)
        };
        let step = env.step(action, &mut obs);
        if step.done {
            total += env.last_return as f64;
            done_eps += 1;
            h.fill(0.0);
            c.fill(0.0);
        }
    }
    Ok(total / episodes as f64)
}

fn main() -> anyhow::Result<()> {
    let cli = Cli::new("quickstart", "E2E R2D2 training on the real stack")
        .flag("steps", "300", "learner steps")
        .flag("actors", "6", "actor threads")
        .flag("env", "catch", "environment")
        .flag("artifacts", "artifacts", "artifact directory");
    let parsed = cli.parse_env().map_err(|e| anyhow::anyhow!("{e}"))?;

    let mut cfg = SystemConfig::default();
    cfg.env.name = parsed.get("env").to_string();
    cfg.env.sticky_action_prob = 0.0; // keep the tiny task learnable fast
    cfg.actors.num_actors = parsed.get_usize("actors")?;
    cfg.learner.max_steps = parsed.get_usize("steps")?;
    cfg.learner.min_replay = 64;
    cfg.learner.target_update_interval = 25;

    println!("[quickstart] loading artifacts + compiling PJRT executables…");
    let (_server, handle) =
        XlaServer::spawn(Path::new(parsed.get("artifacts")), None, true)?;
    let backend = Backend::Xla(handle);

    let random_return = eval_policy(&backend, &cfg, 40, false)?;
    println!("[quickstart] random-policy return: {random_return:.2}");

    println!(
        "[quickstart] training {} learner steps with {} actors on {}…",
        cfg.learner.max_steps, cfg.actors.num_actors, cfg.env.name
    );
    let metrics = Registry::new();
    let report = coordinator::run(&cfg, backend.clone(), metrics)?;
    if let Some(e) = &report.first_error {
        anyhow::bail!("training run failed: {e}");
    }

    println!("\n[quickstart] loss curve (step, loss):");
    for (step, loss) in &report.learner.loss_curve {
        println!("  {step:>5}  {loss:.5}");
    }
    println!(
        "\n[quickstart] {} env steps in {:.1}s ({:.0} steps/s), {} episodes, \
         batcher occupancy {:.1}",
        report.env_steps,
        report.elapsed_seconds,
        report.env_steps_per_sec,
        report.episodes,
        report.mean_batch_occupancy
    );
    println!(
        "[quickstart] loss {:.4} -> {:.4} over {} steps",
        report.learner.first_loss, report.learner.final_loss, report.learner.steps
    );

    let greedy_return = eval_policy(&backend, &cfg, 40, true)?;
    println!(
        "[quickstart] greedy return after training: {greedy_return:.2} \
         (random baseline {random_return:.2})"
    );
    if greedy_return > random_return {
        println!("[quickstart] ✓ policy beats the random baseline");
    } else {
        println!(
            "[quickstart] ✗ policy below random baseline — train longer \
             (--steps 1000) for a clearer signal"
        );
    }
    Ok(())
}
