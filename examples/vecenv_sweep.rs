//! vecenv sweep: envs_per_actor × num_actors on the real coordinator.
//!
//! The paper's Fig. 3 raises the env-step rate by sweeping actor *threads*
//! (4 → 40 → 256) against the batcher; the knee sits at the CPU's
//! hardware-thread count. `vecenv` decouples environments-in-flight from
//! threads consumed, so the same tail is reachable with far fewer
//! threads. This example runs the real dataflow (actors + batcher +
//! learner) on the mock backend over the grid and reports env-steps/sec
//! and mean inference-batch occupancy, then reproduces the same story on
//! the architectural model at paper scale.
//!
//!     cargo run --release --example vecenv_sweep
//!
//! Flags: --actors 1,2,4  --envs 1,2,4,8  --steps N  --env NAME.

use rlarch::cli::Cli;
use rlarch::config::{InferenceMode, SystemConfig};
use rlarch::coordinator;
use rlarch::metrics::Registry;
use rlarch::runtime::{Backend, MockModel, ModelDims};
use rlarch::report::figure::Table;
use rlarch::report::write_csv;
use rlarch::simarch::{
    default_system, synthetic_paper_train_trace, synthetic_paper_trace,
};
use std::sync::Arc;

fn sweep_cfg(env: &str, actors: usize, envs: usize, steps: usize) -> SystemConfig {
    let mut cfg = SystemConfig::default();
    cfg.mode = InferenceMode::Central;
    cfg.env.name = env.to_string();
    cfg.env.step_cost_us = 100; // ALE-class env weight: makes CPU time real
    cfg.actors.num_actors = actors;
    cfg.actors.envs_per_actor = envs;
    cfg.learner.burn_in = 2;
    cfg.learner.unroll_len = 4;
    cfg.learner.seq_overlap = 2;
    cfg.learner.train_batch = 4;
    cfg.learner.min_replay = 16;
    cfg.learner.max_steps = steps;
    cfg.learner.replay_capacity = 1024;
    cfg.batcher.max_batch = 32;
    cfg.batcher.batch_sizes = vec![1, 8, 32];
    cfg.batcher.timeout_us = 500;
    cfg
}

fn main() -> anyhow::Result<()> {
    let cli = Cli::new(
        "vecenv_sweep",
        "envs_per_actor x num_actors sweep on the mock backend",
    )
    .flag("actors", "1,2,4", "actor thread counts")
    .flag("envs", "1,2,4,8", "envs-per-actor counts")
    .flag("steps", "40", "learner steps per grid point")
    .flag("env", "catch", "environment");
    let parsed = cli.parse_env().map_err(|e| anyhow::anyhow!("{e}"))?;
    let actor_counts = parsed.get_usize_list("actors")?;
    let env_counts = parsed.get_usize_list("envs")?;
    let steps = parsed.get_usize("steps")?;
    let env_name = parsed.get("env").to_string();

    println!("# vecenv sweep — real dataflow on the mock backend\n");
    let mut t = Table::new(&[
        "actors",
        "envs/actor",
        "envs in flight",
        "env steps/s",
        "mean batch",
        "episodes",
    ]);
    let mut csv = String::from(
        "actors,envs_per_actor,total_envs,env_steps_per_sec,mean_batch\n",
    );
    for &actors in &actor_counts {
        for &envs in &env_counts {
            let cfg = sweep_cfg(&env_name, actors, envs, steps);
            let dims = ModelDims {
                obs_len: 400,
                hidden: 16,
                num_actions: 4,
                seq_len: cfg.learner.seq_len(),
                train_batch: cfg.learner.train_batch,
            };
            let backend = Backend::Mock(Arc::new(MockModel::new(dims, 11)));
            let report = coordinator::run(&cfg, backend, Registry::new())?;
            t.row(&[
                actors.to_string(),
                envs.to_string(),
                report.total_envs.to_string(),
                format!("{:.0}", report.env_steps_per_sec),
                format!("{:.1}", report.mean_batch_occupancy),
                report.episodes.to_string(),
            ]);
            csv.push_str(&format!(
                "{actors},{envs},{},{},{}\n",
                report.total_envs,
                report.env_steps_per_sec,
                report.mean_batch_occupancy
            ));
        }
    }
    println!("{}", t.to_markdown());
    println!(
        "Reading: at a fixed thread count, envs/actor multiplies both the \
         env-step rate and the inference-batch occupancy — the same lever \
         the paper pulls with more threads.\n"
    );

    // The paper-scale story on the architectural model: the Fig. 3 tail
    // (256 oversubscribed single-env threads) vs small vecenv pools.
    println!("# paper-scale model: Fig. 3 tail with far fewer threads\n");
    let m = default_system(
        synthetic_paper_trace(1, 1, 64),
        synthetic_paper_train_trace(2, 80, 16),
    );
    let mut mt = Table::new(&[
        "topology",
        "threads",
        "envs in flight",
        "env steps/s",
        "batch",
        "vs 256-thread tail",
    ]);
    let tail = m.steady_state(256).env_rate;
    for (threads, envs) in [
        (4usize, 1usize),
        (40, 1),
        (256, 1),
        (4, 8),
        (8, 8),
        (32, 8),
        (16, 16),
    ] {
        let p = m.with_envs_per_actor(envs).steady_state(threads);
        mt.row(&[
            if envs == 1 {
                "single-env".into()
            } else {
                format!("vecenv x{envs}")
            },
            threads.to_string(),
            (threads * envs).to_string(),
            format!("{:.0}", p.env_rate),
            format!("{:.1}", p.batch_size),
            format!("{:.2}x", p.env_rate / tail),
        ]);
    }
    println!("{}", mt.to_markdown());
    let p = write_csv("vecenv_sweep", &csv);
    println!("csv: {}", p.display());
    Ok(())
}
