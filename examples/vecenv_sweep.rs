//! vecenv sweep: pipeline_depth × envs_per_actor × num_actors on the
//! real coordinator.
//!
//! The paper's Fig. 3 raises the env-step rate by sweeping actor *threads*
//! (4 → 40 → 256) against the batcher; the knee sits at the CPU's
//! hardware-thread count. `vecenv` decouples environments-in-flight from
//! threads consumed, and the policy layer's `pipeline_depth` additionally
//! overlaps each thread's env stepping with its in-flight inference. This
//! example runs the real dataflow (actors + batcher + learner) on the
//! mock backend (with injected inference latency, so there is GPU time to
//! hide) over the grid and reports env-steps/sec and mean inference-batch
//! occupancy, then reproduces the same story on the architectural model
//! at paper scale.
//!
//!     cargo run --release --example vecenv_sweep
//!
//! Flags: --actors 1,2,4  --envs 1,2,4,8  --depths 1,2  --steps N
//!        --env NAME  --infer-latency-us L  --batch-native  --json PATH.
//!
//! `--batch-native` steps every grid point's env slots through the SoA
//! engine (`env.batch_native`, DESIGN.md §13) instead of the per-slot
//! path — bit-for-bit identical trajectories, so any rate delta is
//! engine overhead alone.
//!
//! `--json PATH` appends the measured grid (env steps/s, mean/last
//! batch occupancy, batcher launches/s, learner steps/s, a
//! `batch_native` engine tag per row, transport frames/s + bytes/s —
//! identically 0 in-process, live under a `[fleet]` run — plus a unix
//! timestamp) to a JSON array at PATH — the repo's perf trajectory
//! (`BENCH_vecenv.json`) accumulates one entry per recorded run.

use rlarch::cli::Cli;
use rlarch::config::{InferenceMode, SystemConfig};
use rlarch::coordinator;
use rlarch::metrics::Registry;
use rlarch::report::figure::Table;
use rlarch::report::write_csv;
use rlarch::runtime::{Backend, MockModel, ModelDims};
use rlarch::simarch::{
    default_system, synthetic_paper_train_trace, synthetic_paper_trace,
};
use rlarch::util::json::{obj, Value};
use std::sync::Arc;
use std::time::Duration;

fn sweep_cfg(
    env: &str,
    actors: usize,
    envs: usize,
    depth: usize,
    prefetch: usize,
    steps: usize,
    batch_native: bool,
) -> SystemConfig {
    let mut cfg = SystemConfig::default();
    cfg.mode = InferenceMode::Central;
    cfg.env.name = env.to_string();
    cfg.env.step_cost_us = 100; // ALE-class env weight: makes CPU time real
    cfg.env.batch_native = batch_native;
    cfg.actors.num_actors = actors;
    cfg.actors.envs_per_actor = envs;
    cfg.actors.pipeline_depth = depth;
    cfg.learner.burn_in = 2;
    cfg.learner.unroll_len = 4;
    cfg.learner.seq_overlap = 2;
    cfg.learner.train_batch = 4;
    cfg.learner.min_replay = 16;
    cfg.learner.max_steps = steps;
    cfg.learner.prefetch_depth = prefetch;
    cfg.replay.capacity = 1024;
    cfg.batcher.max_batch = 32;
    cfg.batcher.batch_sizes = vec![1, 8, 32];
    cfg.batcher.timeout_us = 500;
    cfg
}

fn main() -> anyhow::Result<()> {
    let cli = Cli::new(
        "vecenv_sweep",
        "pipeline_depth x envs_per_actor x num_actors sweep on the mock backend",
    )
    .flag("actors", "1,2,4", "actor thread counts")
    .flag("envs", "1,2,4,8", "envs-per-actor counts")
    .flag("depths", "1,2", "actor pipeline depths")
    .flag(
        "prefetch-depth",
        "1",
        "learner prefetch depth (1 = serialized seed learner)",
    )
    .flag("steps", "40", "learner steps per grid point")
    .flag("env", "catch", "environment")
    .flag(
        "infer-latency-us",
        "250",
        "injected mock inference latency (GPU time to overlap)",
    )
    .switch(
        "batch-native",
        "step env slots through the batch-native SoA engine (cost only; \
         trajectories are bit-for-bit identical)",
    )
    .flag(
        "json",
        "",
        "append the steps/s grid to this JSON array file (perf trajectory)",
    );
    let parsed = cli.parse_env().map_err(|e| anyhow::anyhow!("{e}"))?;
    let actor_counts = parsed.get_usize_list("actors")?;
    let env_counts = parsed.get_usize_list("envs")?;
    let depth_counts = parsed.get_usize_list("depths")?;
    let prefetch = parsed.get_usize("prefetch-depth")?.max(1);
    let steps = parsed.get_usize("steps")?;
    let latency_us = parsed.get_u64("infer-latency-us")?;
    let env_name = parsed.get("env").to_string();
    let batch_native = parsed.get_switch("batch-native");

    let json_path = parsed.get("json").to_string();
    let mut json_rows: Vec<Value> = Vec::new();

    println!("# vecenv sweep — real dataflow on the mock backend\n");
    let mut t = Table::new(&[
        "actors",
        "envs/actor",
        "depth",
        "envs in flight",
        "env steps/s",
        "mean batch",
        "batcher/s",
        "last batch",
        "learner steps/s",
        "episodes",
    ]);
    let mut csv = String::from(
        "actors,envs_per_actor,pipeline_depth,total_envs,env_steps_per_sec,\
         mean_batch,batcher_steps_per_sec,last_batch_size,learner_steps_per_sec,\
         transport_frames_per_sec,transport_bytes_per_sec\n",
    );
    for &actors in &actor_counts {
        for &envs in &env_counts {
            for &depth in &depth_counts {
                if depth > envs {
                    continue; // clamps to envs anyway: skip duplicates
                }
                let cfg = sweep_cfg(
                    &env_name,
                    actors,
                    envs,
                    depth,
                    prefetch,
                    steps,
                    batch_native,
                );
                let dims = ModelDims {
                    obs_len: 400,
                    hidden: 16,
                    num_actions: 4,
                    seq_len: cfg.learner.seq_len(),
                    train_batch: cfg.learner.train_batch,
                };
                let backend = Backend::Mock(Arc::new(
                    MockModel::new(dims, 11)
                        .with_infer_latency(Duration::from_micros(latency_us)),
                ));
                let metrics = Registry::new();
                let report = coordinator::run(&cfg, backend, metrics.clone())?;
                if let Some(e) = &report.first_error {
                    anyhow::bail!(
                        "grid point actors={actors} envs={envs} depth={depth} \
                         failed: {e}"
                    );
                }
                let learner_rate = report.learner.steps as f64
                    / report.elapsed_seconds.max(1e-9);
                // Batcher cadence + closing occupancy: launches/sec and
                // the size of the last formed batch — the occupancy
                // column of the BENCH_vecenv.json perf trajectory.
                let batcher_rate = report.inference_batches as f64
                    / report.elapsed_seconds.max(1e-9);
                let last_batch = metrics.gauge("batcher.last_batch_size").get();
                // Fleet transport traffic (frames + payload bytes both
                // directions). Identically 0 in-process — the columns
                // exist so a `[fleet]` run's rows land in the same
                // trajectory schema as single-process rows.
                let el = report.elapsed_seconds.max(1e-9);
                let transport_frames = (metrics.counter("fleet.tx_frames").get()
                    + metrics.counter("fleet.rx_frames").get())
                    as f64;
                let transport_bytes = (metrics.counter("fleet.tx_bytes").get()
                    + metrics.counter("fleet.rx_bytes").get())
                    as f64;
                let transport_frames_rate = transport_frames / el;
                let transport_bytes_rate = transport_bytes / el;
                t.row(&[
                    actors.to_string(),
                    envs.to_string(),
                    depth.to_string(),
                    report.total_envs.to_string(),
                    format!("{:.0}", report.env_steps_per_sec),
                    format!("{:.1}", report.mean_batch_occupancy),
                    format!("{batcher_rate:.0}"),
                    format!("{last_batch:.0}"),
                    format!("{learner_rate:.1}"),
                    report.episodes.to_string(),
                ]);
                csv.push_str(&format!(
                    "{actors},{envs},{depth},{},{},{},{batcher_rate},\
                     {last_batch},{learner_rate},{transport_frames_rate},\
                     {transport_bytes_rate}\n",
                    report.total_envs,
                    report.env_steps_per_sec,
                    report.mean_batch_occupancy
                ));
                json_rows.push(obj(&[
                    ("actors", actors.into()),
                    ("envs_per_actor", envs.into()),
                    ("pipeline_depth", depth.into()),
                    ("total_envs", report.total_envs.into()),
                    ("env_steps_per_sec", report.env_steps_per_sec.into()),
                    ("mean_batch", report.mean_batch_occupancy.into()),
                    ("batcher_steps_per_sec", batcher_rate.into()),
                    ("last_batch_size", last_batch.into()),
                    ("learner_steps_per_sec", learner_rate.into()),
                    ("batch_native", batch_native.into()),
                    ("transport_frames_per_sec", transport_frames_rate.into()),
                    ("transport_bytes_per_sec", transport_bytes_rate.into()),
                ]));
            }
        }
    }
    println!("{}", t.to_markdown());
    println!(
        "Reading: at a fixed thread count, envs/actor multiplies both the \
         env-step rate and the inference-batch occupancy — the same lever \
         the paper pulls with more threads — and pipeline depth then hides \
         the env CPU work under the inference round-trip on top of it.\n"
    );

    // The paper-scale story on the architectural model: the Fig. 3 tail
    // (256 oversubscribed single-env threads) vs small vecenv pools,
    // serialized and pipelined.
    println!("# paper-scale model: Fig. 3 tail with far fewer threads\n");
    let m = default_system(
        synthetic_paper_trace(1, 1, 64),
        synthetic_paper_train_trace(2, 80, 16),
    );
    let mut mt = Table::new(&[
        "topology",
        "threads",
        "envs in flight",
        "env steps/s",
        "batch",
        "vs 256-thread tail",
    ]);
    let tail = m.steady_state(256).env_rate;
    for (threads, envs, depth) in [
        (4usize, 1usize, 1usize),
        (40, 1, 1),
        (256, 1, 1),
        (4, 8, 1),
        (4, 8, 2),
        (8, 8, 1),
        (8, 8, 2),
        (32, 8, 1),
        (32, 8, 2),
        (16, 16, 2),
    ] {
        let p = m
            .with_envs_per_actor(envs)
            .with_pipeline_depth(depth)
            .steady_state(threads);
        mt.row(&[
            match (envs, depth) {
                (1, _) => "single-env".into(),
                (_, 1) => format!("vecenv x{envs}"),
                _ => format!("vecenv x{envs} depth {depth}"),
            },
            threads.to_string(),
            (threads * envs).to_string(),
            format!("{:.0}", p.env_rate),
            format!("{:.1}", p.batch_size),
            format!("{:.2}x", p.env_rate / tail),
        ]);
    }
    println!("{}", mt.to_markdown());
    let p = write_csv("vecenv_sweep", &csv);
    println!("csv: {}", p.display());

    // Perf trajectory: append this run's grid to the JSON array so
    // successive recorded runs accumulate (see BENCH_vecenv.json).
    if !json_path.is_empty() {
        // Refuse to clobber a trajectory we cannot parse: a corrupted
        // file (truncated write, merge conflict) must surface as an
        // error, not be silently replaced by a one-entry history.
        let mut runs: Vec<Value> = match std::fs::read_to_string(&json_path) {
            Ok(text) => match Value::parse(&text)
                .ok()
                .and_then(|v| v.as_arr().map(|a| a.to_vec()))
            {
                Some(existing) => existing,
                None => anyhow::bail!(
                    "--json {json_path}: existing file is not a JSON array; \
                     refusing to overwrite the perf trajectory"
                ),
            },
            Err(_) => Vec::new(),
        };
        let ts = std::time::SystemTime::now()
            .duration_since(std::time::UNIX_EPOCH)
            .map(|d| d.as_secs())
            .unwrap_or(0);
        runs.push(obj(&[
            ("bench", "vecenv_sweep".into()),
            ("timestamp_unix", ts.into()),
            ("env", env_name.as_str().into()),
            ("steps", steps.into()),
            ("infer_latency_us", latency_us.into()),
            ("rows", Value::from(json_rows)),
        ]));
        let entries = runs.len();
        std::fs::write(&json_path, Value::from(runs).to_string())?;
        println!("json: {json_path} ({entries} run(s) recorded)");
    }
    Ok(())
}
