//! Ablation A1: SEED-style central inference vs IMPALA-style local
//! inference — the architectural contrast the paper's Fig. 1 draws.
//!
//! Runs the same workload through both coordinator modes and reports
//! throughput, inference-batch occupancy, and per-call efficiency. Uses
//! the real PJRT backend when artifacts are present (pass
//! --backend mock to force the pure-Rust mock for a fast run).

use rlarch::cli::Cli;
use rlarch::config::{InferenceMode, SystemConfig};
use rlarch::coordinator::{self, RunReport};
use rlarch::metrics::Registry;
use rlarch::report::figure::Table;
use rlarch::runtime::{Backend, MockModel, ModelDims, XlaServer};
use std::path::Path;
use std::sync::Arc;

fn run_mode(
    mode: InferenceMode,
    backend: Backend,
    base: &SystemConfig,
) -> anyhow::Result<(RunReport, Registry)> {
    let mut cfg = base.clone();
    cfg.mode = mode;
    let metrics = Registry::new();
    let report = coordinator::run(&cfg, backend, metrics.clone())?;
    if let Some(e) = &report.first_error {
        anyhow::bail!("{:?} run failed: {e}", cfg.mode);
    }
    Ok((report, metrics))
}

fn main() -> anyhow::Result<()> {
    let cli = Cli::new(
        "central_vs_local_inference",
        "SEED (central) vs IMPALA-style (local) inference ablation",
    )
    .flag("steps", "60", "learner steps per mode")
    .flag("actors", "8", "actor threads")
    .flag("env", "grid_pong", "environment")
    .flag("backend", "auto", "auto|xla|mock")
    .flag("artifacts", "artifacts", "artifact directory");
    let parsed = cli.parse_env().map_err(|e| anyhow::anyhow!("{e}"))?;

    let mut cfg = SystemConfig::default();
    cfg.env.name = parsed.get("env").to_string();
    cfg.actors.num_actors = parsed.get_usize("actors")?;
    cfg.learner.max_steps = parsed.get_usize("steps")?;
    cfg.learner.min_replay = 64;

    let artifacts = Path::new(parsed.get("artifacts"));
    let use_xla = match parsed.get("backend") {
        "xla" => true,
        "mock" => false,
        _ => artifacts.join("manifest.json").exists(),
    };

    // Hold the server (if any) so it outlives both runs.
    let mut _server = None;
    let backend = if use_xla {
        println!("[ablation] backend: XLA (PJRT, real artifacts)");
        let (srv, handle) = XlaServer::spawn(artifacts, None, true)?;
        _server = Some(srv);
        Backend::Xla(handle)
    } else {
        println!("[ablation] backend: mock (pure Rust)");
        let dims = ModelDims {
            obs_len: 400,
            hidden: 128,
            num_actions: 4,
            seq_len: cfg.learner.seq_len(),
            train_batch: cfg.learner.train_batch,
        };
        Backend::Mock(Arc::new(MockModel::new(dims, 2020)))
    };

    let (central, cmetrics) = run_mode(InferenceMode::Central, backend.clone(), &cfg)?;
    let (local, _lmetrics) = run_mode(InferenceMode::Local, backend.clone(), &cfg)?;

    let infer_mean = cmetrics.timer("batcher.infer_seconds").snapshot();
    let mut t = Table::new(&[
        "mode",
        "env steps/s",
        "episodes",
        "inference calls",
        "mean batch",
        "steps/call",
    ]);
    t.row(&[
        "central (SEED)".into(),
        format!("{:.0}", central.env_steps_per_sec),
        central.episodes.to_string(),
        central.inference_batches.to_string(),
        format!("{:.2}", central.mean_batch_occupancy),
        format!(
            "{:.2}",
            central.env_steps as f64 / central.inference_batches.max(1) as f64
        ),
    ]);
    t.row(&[
        "local (IMPALA-style)".into(),
        format!("{:.0}", local.env_steps_per_sec),
        local.episodes.to_string(),
        local.env_steps.to_string(), // one call per step
        "1.00".into(),
        "1.00".into(),
    ]);
    println!("\n{}", t.to_markdown());
    println!(
        "central mode amortized {:.1} actor steps per accelerator call \
         (mean batched-infer latency {:.2}ms); local mode pays one call per \
         step — the paper's Fig. 1 architectural contrast.",
        central.mean_batch_occupancy,
        infer_mean.mean() * 1e3
    );
    let path = rlarch::report::write_csv("ablation_central_vs_local", &t.to_csv());
    println!("csv: {}", path.display());
    Ok(())
}
