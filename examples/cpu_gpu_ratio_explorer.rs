//! Design-space exploration over the paper's *CPU/GPU ratio* metric
//! (Conclusion 3): sweep CPU hardware threads x GPU SMs on the
//! calibrated system model and report throughput, utilization, and
//! energy-per-step for each design point — including the DGX-1 (1/16)
//! and DGX-A100 (1/4) corners the paper calls out.
//!
//!     cargo run --release --example cpu_gpu_ratio_explorer

use rlarch::cli::Cli;
use rlarch::report::figure::Table;
use rlarch::simarch::{default_system, TraceSet};
use std::path::Path;

fn main() -> anyhow::Result<()> {
    let cli = Cli::new(
        "cpu_gpu_ratio_explorer",
        "sweep CPU threads x GPU SMs over the CPU/GPU-ratio design space",
    )
    .flag("threads", "10,20,40,80,160", "CPU hardware-thread counts")
    .flag("sms", "20,40,80,160", "GPU SM counts")
    .flag("actors-per-thread", "4", "actor oversubscription factor")
    .flag("artifacts", "artifacts", "artifact directory");
    let parsed = cli.parse_env().map_err(|e| anyhow::anyhow!("{e}"))?;

    let ts = TraceSet::load(Path::new(parsed.get("artifacts")))?;
    let base = default_system(
        ts.find("infer_paper_scale").expect("run `make artifacts`").clone(),
        ts.find("train_paper_scale").expect("train trace").clone(),
    );
    let threads = parsed.get_usize_list("threads")?;
    let sms = parsed.get_usize_list("sms")?;
    let ovs = parsed.get_usize("actors-per-thread")?;

    let mut t = Table::new(&[
        "threads", "SMs", "CPU/GPU", "env steps/s", "GPU util", "power W",
        "energy mJ/step",
    ]);
    let mut best: Option<(f64, String)> = None;
    for &th in &threads {
        for &sm in &sms {
            let m = base.with_threads(th).with_sms(sm);
            let p = m.steady_state(th * ovs);
            let energy_mj = p.power_w / p.env_rate * 1e3;
            let ratio = th as f64 / sm as f64;
            t.row(&[
                th.to_string(),
                sm.to_string(),
                format!("{ratio:.3}"),
                format!("{:.0}", p.env_rate),
                format!("{:.2}", p.gpu_util),
                format!("{:.0}", p.power_w),
                format!("{energy_mj:.3}"),
            ]);
            let score = p.env_rate / p.power_w;
            if best.as_ref().map(|(s, _)| score > *s).unwrap_or(true) {
                best = Some((score, format!("{th} threads / {sm} SMs (ratio {ratio:.2})")));
            }
        }
    }
    println!("{}", t.to_markdown());

    // The named systems from the paper's Conclusion 3.
    let mut named = Table::new(&["system", "threads", "SMs", "ratio", "env steps/s",
                                 "energy mJ/step"]);
    for (name, th, sm) in [
        ("DGX-1 (8xV100)", 40usize, 640usize),
        ("DGX-A100", 256, 864),
        ("ratio-1 design", 80, 80),
        ("paper recommendation (>=1)", 160, 80),
    ] {
        let m = base.with_threads(th).with_sms(sm);
        let p = m.steady_state(th * ovs);
        named.row(&[
            name.into(),
            th.to_string(),
            sm.to_string(),
            format!("{:.3}", th as f64 / sm as f64),
            format!("{:.0}", p.env_rate),
            format!("{:.3}", p.power_w / p.env_rate * 1e3),
        ]);
    }
    println!("{}", named.to_markdown());
    if let Some((_, b)) = best {
        println!("best perf/W in sweep: {b}");
    }
    println!(
        "paper Conclusion 3: CPU/GPU ratio should be >= 1 — DGX-1 is 1/16 \
         (16x short), DGX-A100 1/4 (4x short)."
    );
    let path = rlarch::report::write_csv("cpu_gpu_ratio_explorer", &t.to_csv());
    println!("csv: {}", path.display());
    Ok(())
}
