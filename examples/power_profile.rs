//! Power/efficiency profile (the Fig. 3 right-axis story): sweep actor
//! counts on the calibrated system model and report GPU power,
//! perf-per-Watt, and energy to generate a fixed frame budget —
//! demonstrating the paper's observation that perf/W keeps improving
//! with actor count because idle GPU power (~70 W) dominates at low
//! utilization.

use rlarch::cli::Cli;
use rlarch::report::figure::{ascii_bar, Table};
use rlarch::simarch::{default_system, TraceSet};
use std::path::Path;

fn main() -> anyhow::Result<()> {
    let cli = Cli::new("power_profile", "GPU power & efficiency vs actor count")
        .flag("actors", "4,8,16,32,40,64,128,256", "actor counts")
        .flag("frames", "10000000", "frame budget for the energy column")
        .flag("artifacts", "artifacts", "artifact directory");
    let parsed = cli.parse_env().map_err(|e| anyhow::anyhow!("{e}"))?;

    let ts = TraceSet::load(Path::new(parsed.get("artifacts")))?;
    let m = default_system(
        ts.find("infer_paper_scale").expect("run `make artifacts`").clone(),
        ts.find("train_paper_scale").expect("train trace").clone(),
    );
    let actors = parsed.get_usize_list("actors")?;
    let frames = parsed.get_u64("frames")?;

    let mut t = Table::new(&[
        "actors", "GPU util", "power W", "perf/W", "", "energy kJ / 10M frames",
    ]);
    for &n in &actors {
        let p = m.steady_state(n);
        let seconds = frames as f64 / p.env_rate;
        let energy_kj = p.power_w * seconds / 1e3;
        t.row(&[
            n.to_string(),
            format!("{:.2}", p.gpu_util),
            format!("{:.0}", p.power_w),
            format!("{:.1}", p.perf_per_watt),
            ascii_bar(p.perf_per_watt / 600.0, 20),
            format!("{energy_kj:.1}"),
        ]);
    }
    println!("{}", t.to_markdown());
    println!(
        "idle floor {:.0} W; TDP {:.0} W. Energy per task falls monotonically \
         with actor count — the paper's power-efficiency conclusion.",
        m.power.cfg.idle_w, m.power.cfg.max_w
    );
    let path = rlarch::report::write_csv("power_profile", &t.to_csv());
    println!("csv: {}", path.display());
    Ok(())
}
