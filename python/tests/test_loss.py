"""R2D2 / V-trace loss semantics + optimizer behaviour."""

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from compile import loss, model, optim

SMALL = model.AgentConfig(obs_size=6, obs_channels=2, num_actions=3,
                          conv1_filters=4, conv2_filters=8, torso_dim=16,
                          lstm_hidden=16, head_dim=8)
LCFG = loss.R2d2Config(burn_in=2, unroll_len=6, n_step=2)


def _batch(rng, b, t, cfg):
    return (
        jnp.asarray(rng.random((b, t) + cfg.obs_shape), jnp.float32),
        jnp.asarray(rng.integers(0, cfg.num_actions, (b, t)), jnp.int32),
        jnp.asarray(rng.standard_normal((b, t)), jnp.float32),
        jnp.full((b, t), 0.99, jnp.float32),
    )


@pytest.fixture(scope="module")
def setup():
    params = model.init_params(jax.random.PRNGKey(0), SMALL)
    target = jax.tree_util.tree_map(lambda x: x.copy(), params)
    opt = optim.init_opt_state(params)
    return params, target, opt


class TestNStepTargets:
    def test_zero_td_when_consistent(self):
        # If q_online == q_target == h(const/(1-gamma)) and reward==const
        # with no rescale (check in raw space via n=1, gamma through
        # discounts), td should be ~0 for a self-consistent value fn.
        t, b, a = 6, 2, 3
        gamma = 0.9
        r = 1.0
        v = r / (1.0 - gamma)  # un-rescaled fixed point
        from compile.kernels.ref import value_rescale_ref as h
        q = jnp.full((t, b, a), float(h(jnp.float32(v))))
        actions = jnp.zeros((t, b), jnp.int32)
        rewards = jnp.full((t, b), r)
        discounts = jnp.full((t, b), gamma)
        td, valid = loss.n_step_targets(q, q, actions, rewards, discounts, 1)
        assert valid.shape == (t,)
        np.testing.assert_allclose(np.asarray(td[:-1]), 0.0, atol=1e-4)

    def test_tail_masked(self):
        t, b, a, n = 8, 3, 4, 3
        rng = np.random.default_rng(0)
        q = jnp.asarray(rng.standard_normal((t, b, a)), jnp.float32)
        actions = jnp.zeros((t, b), jnp.int32)
        rewards = jnp.ones((t, b), jnp.float32)
        discounts = jnp.full((t, b), 0.9, jnp.float32)
        td, valid = loss.n_step_targets(q, q, actions, rewards, discounts, n)
        assert np.asarray(valid)[-n:].sum() == 0
        np.testing.assert_array_equal(np.asarray(td[-n:]), 0.0)

    def test_terminal_cuts_bootstrap(self):
        # discount 0 at t means the target for t is just the reward sum up
        # to the terminal — changing q beyond it must not change td[t].
        t, b, a, n = 6, 1, 2, 2
        rng = np.random.default_rng(1)
        q1 = jnp.asarray(rng.standard_normal((t, b, a)), jnp.float32)
        q2 = q1.at[3:].add(5.0)  # perturb after the terminal at t=2
        actions = jnp.zeros((t, b), jnp.int32)
        rewards = jnp.ones((t, b), jnp.float32)
        discounts = jnp.asarray(
            [[0.9], [0.9], [0.0], [0.9], [0.9], [0.9]], jnp.float32)
        td1, _ = loss.n_step_targets(q1, q1, actions, rewards, discounts, n)
        td2, _ = loss.n_step_targets(q2, q2, actions, rewards, discounts, n)
        # t=1: bootstrap at t=3 is cut by discount[2]=0 -> td equal even
        # though q at t=3 changed (selected q at t=1 unchanged).
        np.testing.assert_allclose(td1[1], td2[1], atol=1e-5)


class TestR2d2Loss:
    def test_loss_finite_and_priorities_shape(self, setup):
        params, target, _ = setup
        rng = np.random.default_rng(2)
        obs, acts, rews, disc = _batch(rng, 3, LCFG.seq_len, SMALL)
        h0, c0 = model.initial_state(3, SMALL)
        l, (prio, mean_td) = loss.r2d2_loss(
            params, target, obs, acts, rews, disc, h0, c0, SMALL, LCFG)
        assert np.isfinite(float(l))
        assert prio.shape == (3,)
        assert bool(jnp.all(prio >= 0))

    def test_train_step_reduces_loss_on_fixed_batch(self, setup):
        params, target, opt = setup
        rng = np.random.default_rng(3)
        obs, acts, rews, disc = _batch(rng, 4, LCFG.seq_len, SMALL)
        h0, c0 = model.initial_state(4, SMALL)
        step = jax.jit(lambda p, t, o, *a: loss.r2d2_train_step(
            p, t, o, *a, agent_cfg=SMALL, cfg=LCFG))
        out = step(params, target, opt, obs, acts, rews, disc, h0, c0)
        first = float(out[2])
        for _ in range(10):
            out = step(out[0], target, out[1], obs, acts, rews, disc, h0, c0)
        assert float(out[2]) < first

    def test_burn_in_gradient_isolation(self, setup):
        # Gradients must not flow through burn-in: perturbing burn-in-only
        # rewards changes nothing (rewards before burn_in are unused).
        params, target, _ = setup
        rng = np.random.default_rng(4)
        obs, acts, rews, disc = _batch(rng, 2, LCFG.seq_len, SMALL)
        h0, c0 = model.initial_state(2, SMALL)
        l1, _ = loss.r2d2_loss(params, target, obs, acts, rews, disc,
                               h0, c0, SMALL, LCFG)
        rews2 = rews.at[:, : LCFG.burn_in].add(10.0)
        l2, _ = loss.r2d2_loss(params, target, obs, acts, rews2, disc,
                               h0, c0, SMALL, LCFG)
        np.testing.assert_allclose(float(l1), float(l2), rtol=1e-6)


class TestAdam:
    def test_descends_quadratic(self):
        p = {"w": jnp.asarray([5.0, -3.0])}
        opt = optim.init_opt_state(p)
        cfg = optim.AdamConfig(lr=0.1)
        for _ in range(200):
            g = jax.tree_util.tree_map(lambda x: 2 * x, p)
            p, opt, _ = optim.adam_update(p, g, opt, cfg)
        np.testing.assert_allclose(np.asarray(p["w"]), 0.0, atol=1e-2)

    def test_step_counter_increments(self):
        p = {"w": jnp.ones((2,))}
        opt = optim.init_opt_state(p)
        g = {"w": jnp.ones((2,))}
        _, opt, _ = optim.adam_update(p, g, opt, optim.AdamConfig())
        assert int(opt[0]) == 1

    def test_global_norm_clip(self):
        g = {"a": jnp.asarray([30.0, 40.0])}  # norm 50
        clipped, norm = optim.clip_by_global_norm(g, 5.0)
        assert abs(float(norm) - 50.0) < 1e-4
        np.testing.assert_allclose(
            np.asarray(clipped["a"]), [3.0, 4.0], rtol=1e-4)

    def test_clip_noop_below_threshold(self):
        g = {"a": jnp.asarray([0.3, 0.4])}
        clipped, _ = optim.clip_by_global_norm(g, 5.0)
        np.testing.assert_allclose(np.asarray(clipped["a"]), [0.3, 0.4],
                                   rtol=1e-6)


class TestVtrace:
    def test_returns_match_onpolicy_td_lambda1(self):
        # With rho = c = 1 (on-policy), vs is the lambda=1 return.
        t, b = 5, 2
        rng = np.random.default_rng(5)
        values = jnp.asarray(rng.standard_normal((t, b)), jnp.float32)
        rewards = jnp.asarray(rng.standard_normal((t, b)), jnp.float32)
        discounts = jnp.full((t, b), 0.9, jnp.float32)
        boot = jnp.asarray(rng.standard_normal((b,)), jnp.float32)
        ones = jnp.ones((t, b), jnp.float32)
        vs = loss.vtrace_returns(values, rewards, discounts, ones, ones, boot)
        # Explicit Monte-Carlo + bootstrap computation.
        expected = np.zeros((t, b), np.float32)
        vnp, rnp, dnp = map(np.asarray, (values, rewards, discounts))
        bootnp = np.asarray(boot)
        for bi in range(b):
            acc = bootnp[bi]
            for ti in reversed(range(t)):
                acc = rnp[ti, bi] + dnp[ti, bi] * acc
                expected[ti, bi] = acc
        np.testing.assert_allclose(np.asarray(vs), expected, rtol=1e-4,
                                   atol=1e-4)

    def test_train_step_runs_and_descends(self):
        vcfg = loss.VtraceConfig(unroll_len=5)
        vp = model.init_vtrace_params(jax.random.PRNGKey(2), SMALL)
        vopt = optim.init_opt_state(vp)
        rng = np.random.default_rng(6)
        obs, acts, rews, disc = _batch(rng, 3, 5, SMALL)
        blog = jnp.zeros((3, 5, SMALL.num_actions), jnp.float32)
        h0, c0 = model.initial_state(3, SMALL)
        step = jax.jit(lambda p, o, *a: loss.vtrace_train_step(
            p, o, *a, agent_cfg=SMALL, cfg=vcfg))
        out = step(vp, vopt, obs, acts, rews, disc, blog, h0, c0)
        assert np.isfinite(float(out[2]))
        out2 = step(out[0], out[1], obs, acts, rews, disc, blog, h0, c0)
        assert np.isfinite(float(out2[2]))
