"""L2 model: shapes, determinism, scan-vs-static-unroll equivalence."""

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from compile import model, nn

CFG = model.AgentConfig()
SMALL = model.AgentConfig(obs_size=6, obs_channels=2, num_actions=3,
                          conv1_filters=4, conv2_filters=8, torso_dim=16,
                          lstm_hidden=16, head_dim=8)


@pytest.fixture(scope="module")
def params():
    return model.init_params(jax.random.PRNGKey(0), CFG)


@pytest.fixture(scope="module")
def small_params():
    return model.init_params(jax.random.PRNGKey(0), SMALL)


def _obs(rng, b, cfg, t=None):
    shape = (b,) + cfg.obs_shape if t is None else (t, b) + cfg.obs_shape
    return jnp.asarray(rng.random(shape), jnp.float32)


class TestInference:
    def test_shapes(self, params):
        rng = np.random.default_rng(0)
        b = 8
        h, c = model.initial_state(b, CFG)
        q, h2, c2 = model.apply_inference(params, h, c, _obs(rng, b, CFG), CFG)
        assert q.shape == (b, CFG.num_actions)
        assert h2.shape == (b, CFG.lstm_hidden)
        assert c2.shape == (b, CFG.lstm_hidden)

    def test_deterministic(self, params):
        rng = np.random.default_rng(1)
        obs = _obs(rng, 4, CFG)
        h, c = model.initial_state(4, CFG)
        q1, _, _ = model.apply_inference(params, h, c, obs, CFG)
        q2, _, _ = model.apply_inference(params, h, c, obs, CFG)
        np.testing.assert_array_equal(np.asarray(q1), np.asarray(q2))

    def test_batch_elements_independent(self, params):
        # q for element 0 must not depend on element 1's observation.
        rng = np.random.default_rng(2)
        obs_a, obs_b = _obs(rng, 2, CFG), _obs(rng, 2, CFG)
        obs_b = obs_b.at[0].set(obs_a[0])
        h, c = model.initial_state(2, CFG)
        qa, _, _ = model.apply_inference(params, h, c, obs_a, CFG)
        qb, _, _ = model.apply_inference(params, h, c, obs_b, CFG)
        np.testing.assert_allclose(qa[0], qb[0], rtol=1e-5, atol=1e-6)

    def test_state_carries_information(self, params):
        # Same obs, different states -> different q (recurrence is live).
        rng = np.random.default_rng(3)
        obs = _obs(rng, 1, CFG)
        h0, c0 = model.initial_state(1, CFG)
        h1 = h0 + 0.5
        qa, _, _ = model.apply_inference(params, h0, c0, obs, CFG)
        qb, _, _ = model.apply_inference(params, h1, c0, obs, CFG)
        assert not np.allclose(np.asarray(qa), np.asarray(qb))


class TestUnroll:
    def test_scan_matches_static(self, small_params):
        rng = np.random.default_rng(4)
        t, b = 6, 3
        obs = _obs(rng, b, SMALL, t=t)
        h0, c0 = model.initial_state(b, SMALL)
        q1, (h1, c1) = model.unroll(small_params, h0, c0, obs, SMALL)
        q2, (h2, c2) = model.unroll_static(small_params, h0, c0, obs, SMALL)
        np.testing.assert_allclose(q1, q2, rtol=1e-4, atol=1e-5)
        np.testing.assert_allclose(h1, h2, rtol=1e-4, atol=1e-5)
        np.testing.assert_allclose(c1, c2, rtol=1e-4, atol=1e-5)

    def test_unroll_equals_stepwise_inference(self, small_params):
        rng = np.random.default_rng(5)
        t, b = 4, 2
        obs = _obs(rng, b, SMALL, t=t)
        h, c = model.initial_state(b, SMALL)
        q_seq, _ = model.unroll(small_params, h, c, obs, SMALL)
        for i in range(t):
            q, h, c = model.apply_inference(small_params, h, c, obs[i], SMALL)
            np.testing.assert_allclose(q_seq[i], q, rtol=1e-4, atol=1e-5)


class TestParams:
    def test_param_count_formula(self, params):
        # Hand-derived for the default config.
        expected = (
            3 * 3 * 4 * 16 + 16            # conv1
            + 3 * 3 * 16 * 32 + 32          # conv2
            + 800 * 128 + 128               # torso dense
            + 128 * 512 + 128 * 512 + 512   # lstm
            + 128 * 64 + 64                 # head
            + 64 * 1 + 1                    # value
            + 64 * 4 + 4                    # advantage
        )
        assert nn.param_count(params) == expected

    def test_flat_specs_sorted_and_stable(self, params):
        specs = nn.flat_param_specs(params)
        names = [s[0] for s in specs]
        assert names == sorted(names)
        assert len(names) == len(set(names))
        # jax dict-pytree order must match tree_leaves order.
        leaves = jax.tree_util.tree_leaves(params)
        assert [tuple(l.shape) for l in leaves] == [s[1] for s in specs]

    def test_conv_out_dim(self):
        assert CFG.conv_out_dim == 5 * 5 * 32
        assert model.AgentConfig(obs_size=9).conv_out_dim == 5 * 5 * 32


class TestVtraceAgent:
    def test_unroll_shapes(self):
        vp = model.init_vtrace_params(jax.random.PRNGKey(1), SMALL)
        rng = np.random.default_rng(6)
        t, b = 5, 3
        obs = _obs(rng, b, SMALL, t=t)
        h0, c0 = model.initial_state(b, SMALL)
        logits, values, (h, c) = model.vtrace_unroll(vp, h0, c0, obs, SMALL)
        assert logits.shape == (t, b, SMALL.num_actions)
        assert values.shape == (t, b)
        assert h.shape == (b, SMALL.lstm_hidden)
