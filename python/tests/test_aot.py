"""AOT pipeline: flat ABI, HLO-text lowering, tensor-bundle format."""

import json
import os

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from compile import aot, loss, model, optim

SMALL = model.AgentConfig(obs_size=6, obs_channels=2, num_actions=3,
                          conv1_filters=4, conv2_filters=8, torso_dim=16,
                          lstm_hidden=16, head_dim=8)
LCFG = loss.R2d2Config(burn_in=1, unroll_len=4, n_step=1)


@pytest.fixture(scope="module")
def params():
    return model.init_params(jax.random.PRNGKey(0), SMALL)


class TestFlatAbi:
    def test_inference_flat_matches_tree_call(self, params):
        fn, flat = aot.build_inference(params, SMALL, 4)
        rng = np.random.default_rng(0)
        flat = list(flat)
        flat[-1] = jnp.asarray(rng.random(flat[-1].shape), jnp.float32)
        q_flat, h_flat, c_flat = fn(*flat)
        h0, c0 = model.initial_state(4, SMALL)
        q, h, c = model.apply_inference(params, h0, c0, flat[-1], SMALL)
        np.testing.assert_allclose(q_flat, q, rtol=1e-5, atol=1e-6)
        np.testing.assert_allclose(h_flat, h, rtol=1e-5, atol=1e-6)

    def test_train_flat_roundtrip(self, params):
        opt = optim.init_opt_state(params)
        fn, flat = aot.build_train(params, opt, SMALL, LCFG, batch=2)
        outs = fn(*flat)
        n_p = len(jax.tree_util.tree_leaves(params))
        n_o = len(jax.tree_util.tree_leaves(opt))
        # outputs: params' + opt' + (loss, priorities, gnorm)
        assert len(outs) == n_p + n_o + 3
        assert outs[n_p + n_o].shape == ()       # loss
        assert outs[n_p + n_o + 1].shape == (2,)  # priorities
        # param shapes preserved in ABI order.
        for a, b in zip(outs[:n_p], jax.tree_util.tree_leaves(params)):
            assert a.shape == b.shape

    def test_train_abi_input_count(self, params):
        opt = optim.init_opt_state(params)
        fn, flat = aot.build_train(params, opt, SMALL, LCFG, batch=2)
        n_p = len(jax.tree_util.tree_leaves(params))
        n_o = len(jax.tree_util.tree_leaves(opt))
        assert len(flat) == 2 * n_p + n_o + 6


class TestHloText:
    def test_lowering_produces_parseable_hlo(self, params):
        fn, flat = aot.build_inference(params, SMALL, 2)
        lowered = jax.jit(fn).lower(*[aot.spec_of(a) for a in flat])
        text = aot.to_hlo_text(lowered)
        assert "ENTRY" in text
        assert "HloModule" in text
        # return_tuple=True -> root is a tuple.
        from compile import hlo_cost
        comps = hlo_cost.parse_hlo_computations(text)
        assert "__entry__" in comps


class TestTensorBundle:
    def test_roundtrip_layout(self, tmp_path):
        path = os.path.join(tmp_path, "t.bin")
        a = np.arange(6, dtype=np.float32).reshape(2, 3)
        b = np.asarray([7], dtype=np.int32)
        aot.write_tensor_bundle(path, [("a", a), ("b", b)])
        with open(path, "rb") as f:
            raw = f.read()
        assert raw[:16] == aot.TENSOR_BUNDLE_MAGIC
        hlen = int.from_bytes(raw[16:24], "little")
        header = json.loads(raw[24: 24 + hlen])
        assert [h["name"] for h in header] == ["a", "b"]
        payload = raw[24 + hlen:]
        a2 = np.frombuffer(
            payload[header[0]["offset"]: header[0]["offset"]
                    + header[0]["nbytes"]], np.float32).reshape(2, 3)
        np.testing.assert_array_equal(a2, a)
        b2 = np.frombuffer(
            payload[header[1]["offset"]: header[1]["offset"]
                    + header[1]["nbytes"]], np.int32)
        assert int(b2[0]) == 7


ARTIFACT_DIR = os.path.join(os.path.dirname(__file__), "..", "..",
                            "artifacts")


@pytest.mark.skipif(
    not os.path.exists(os.path.join(ARTIFACT_DIR, "manifest.json")),
    reason="run `make artifacts` first")
class TestEmittedArtifacts:
    @pytest.fixture(scope="class")
    def manifest(self):
        with open(os.path.join(ARTIFACT_DIR, "manifest.json")) as f:
            return json.load(f)

    def test_manifest_lists_all_artifacts(self, manifest):
        for name, meta in manifest["artifacts"].items():
            assert os.path.exists(os.path.join(ARTIFACT_DIR, meta["path"])), name

    def test_param_specs_match_init_bundle(self, manifest):
        with open(os.path.join(ARTIFACT_DIR, "init_params.bin"), "rb") as f:
            raw = f.read()
        hlen = int.from_bytes(raw[16:24], "little")
        header = json.loads(raw[24: 24 + hlen])
        n_p = manifest["init"]["params"]
        bundle_p = [h for h in header if h["name"].startswith("p")
                    and not h["name"].startswith(("vp",))][:n_p]
        for spec, h in zip(manifest["param_specs"], bundle_p):
            assert spec["shape"] == h["shape"], (spec, h)

    def test_kernel_trace_has_train_and_infer(self):
        with open(os.path.join(ARTIFACT_DIR, "kernel_trace.json")) as f:
            traces = json.load(f)["traces"]
        names = {t["artifact"] for t in traces}
        assert any(n.startswith("infer") for n in names)
        assert "train_unrolled" in names

    def test_train_inputs_match_r2d2_config(self, manifest):
        train = manifest["artifacts"]["train"]
        t = manifest["r2d2"]["seq_len"]
        b = manifest["r2d2"]["train_batch"]
        obs_like = [i for i in train["inputs"] if len(i["shape"]) == 5]
        assert obs_like and obs_like[0]["shape"][:2] == [b, t]
