"""L1 correctness: Pallas kernels vs pure-jnp oracles (hypothesis sweeps)."""

import jax
import jax.numpy as jnp
import numpy as np
import pytest
from hypothesis import given, settings, strategies as st

from compile.kernels import (
    dueling_head,
    dueling_head_ref,
    lstm_cell,
    lstm_cell_ref,
    lstm_vmem_bytes,
    value_rescale_inv_ref,
    value_rescale_ref,
)

_SETTINGS = dict(max_examples=25, deadline=None)


def _rand(rng, *shape, scale=1.0, dtype=np.float32):
    return jnp.asarray(rng.standard_normal(shape) * scale, dtype)


def _lstm_inputs(rng, b, i, h, dtype=np.float32):
    return (
        _rand(rng, b, i, dtype=dtype),
        _rand(rng, b, h, dtype=dtype),
        _rand(rng, b, h, dtype=dtype),
        _rand(rng, i, 4 * h, scale=0.2, dtype=dtype),
        _rand(rng, h, 4 * h, scale=0.2, dtype=dtype),
        _rand(rng, 4 * h, scale=0.2, dtype=dtype),
    )


class TestLstmCell:
    @settings(**_SETTINGS)
    @given(
        b=st.integers(1, 17),
        i=st.integers(1, 24),
        h=st.integers(1, 24),
        block_b=st.integers(1, 9),
        seed=st.integers(0, 2**31 - 1),
    )
    def test_matches_ref_across_shapes(self, b, i, h, block_b, seed):
        rng = np.random.default_rng(seed)
        args = _lstm_inputs(rng, b, i, h)
        h1, c1 = lstm_cell(*args, block_b=block_b)
        h2, c2 = lstm_cell_ref(*args)
        np.testing.assert_allclose(h1, h2, rtol=1e-5, atol=1e-5)
        np.testing.assert_allclose(c1, c2, rtol=1e-5, atol=1e-5)

    def test_agent_sized(self):
        rng = np.random.default_rng(0)
        args = _lstm_inputs(rng, 32, 128, 128)
        h1, c1 = lstm_cell(*args)
        h2, c2 = lstm_cell_ref(*args)
        np.testing.assert_allclose(h1, h2, rtol=1e-5, atol=1e-5)
        np.testing.assert_allclose(c1, c2, rtol=1e-5, atol=1e-5)

    def test_batch_not_multiple_of_block(self):
        rng = np.random.default_rng(1)
        args = _lstm_inputs(rng, 7, 16, 16)
        h1, c1 = lstm_cell(*args, block_b=4)
        h2, c2 = lstm_cell_ref(*args)
        np.testing.assert_allclose(h1, h2, rtol=1e-5, atol=1e-5)
        np.testing.assert_allclose(c1, c2, rtol=1e-5, atol=1e-5)

    def test_bf16_inputs(self):
        rng = np.random.default_rng(2)
        args = _lstm_inputs(rng, 8, 16, 16, dtype=jnp.bfloat16)
        h1, c1 = lstm_cell(*args)
        h2, c2 = lstm_cell_ref(*args)
        np.testing.assert_allclose(
            np.asarray(h1, np.float32), np.asarray(h2, np.float32),
            rtol=5e-2, atol=5e-2)
        np.testing.assert_allclose(
            np.asarray(c1, np.float32), np.asarray(c2, np.float32),
            rtol=5e-2, atol=5e-2)

    def test_state_bounded(self):
        # |h| <= 1 always (tanh(sigmoid-gated cell)); catches gate-order bugs.
        rng = np.random.default_rng(3)
        args = _lstm_inputs(rng, 16, 32, 32)
        h1, _ = lstm_cell(*args)
        assert float(jnp.max(jnp.abs(h1))) <= 1.0 + 1e-6

    def test_grad_matches_ref(self):
        rng = np.random.default_rng(4)
        args = _lstm_inputs(rng, 4, 8, 8)

        def loss_kernel(*a):
            h, c = lstm_cell(*a)
            return jnp.sum(h * h) + jnp.sum(jnp.abs(c))

        def loss_ref(*a):
            h, c = lstm_cell_ref(*a)
            return jnp.sum(h * h) + jnp.sum(jnp.abs(c))

        g1 = jax.grad(loss_kernel, argnums=(0, 3, 4, 5))(*args)
        g2 = jax.grad(loss_ref, argnums=(0, 3, 4, 5))(*args)
        for a, b in zip(g1, g2):
            np.testing.assert_allclose(a, b, rtol=1e-4, atol=1e-5)

    def test_vmem_estimate_matches_hand_computation(self):
        # block_b=8, I=128, H=128 fp32: hand-derived footprint.
        act = 8 * (128 + 2 * 128)
        gates = 8 * 512
        outs = 8 * 256
        weights = 512 * (128 + 128 + 1)
        assert lstm_vmem_bytes(8, 128, 128) == 4 * (act + gates + outs + weights)

    def test_vmem_under_tpu_budget(self):
        # Default agent tile must fit comfortably in a ~16 MiB VMEM core.
        assert lstm_vmem_bytes(8, 128, 128) < 1 << 21  # < 2 MiB


class TestDuelingHead:
    @settings(**_SETTINGS)
    @given(
        b=st.integers(1, 33),
        a=st.integers(1, 18),
        block_b=st.integers(1, 16),
        seed=st.integers(0, 2**31 - 1),
    )
    def test_matches_ref_across_shapes(self, b, a, block_b, seed):
        rng = np.random.default_rng(seed)
        v = _rand(rng, b, 1)
        adv = _rand(rng, b, a)
        np.testing.assert_allclose(
            dueling_head(v, adv, block_b=block_b),
            dueling_head_ref(v, adv),
            rtol=1e-5, atol=1e-6)

    def test_identifiability(self):
        # Adding a constant to the advantage stream must not change q.
        rng = np.random.default_rng(5)
        v, adv = _rand(rng, 8, 1), _rand(rng, 8, 4)
        q1 = dueling_head(v, adv)
        q2 = dueling_head(v, adv + 3.7)
        np.testing.assert_allclose(q1, q2, rtol=1e-4, atol=1e-5)

    def test_grad_matches_ref(self):
        rng = np.random.default_rng(6)
        v, adv = _rand(rng, 4, 1), _rand(rng, 4, 5)
        g1 = jax.grad(lambda a, b: jnp.sum(dueling_head(a, b) ** 2),
                      argnums=(0, 1))(v, adv)
        g2 = jax.grad(lambda a, b: jnp.sum(dueling_head_ref(a, b) ** 2),
                      argnums=(0, 1))(v, adv)
        for x, y in zip(g1, g2):
            np.testing.assert_allclose(x, y, rtol=1e-4, atol=1e-5)


class TestValueRescale:
    @settings(**_SETTINGS)
    @given(st.lists(st.floats(-1e4, 1e4), min_size=1, max_size=64))
    def test_inverse_roundtrip(self, xs):
        x = jnp.asarray(xs, jnp.float32)
        y = value_rescale_inv_ref(value_rescale_ref(x))
        np.testing.assert_allclose(y, x, rtol=1e-3, atol=1e-3)

    def test_monotonic_and_compressive(self):
        x = jnp.linspace(-100.0, 100.0, 201)
        y = value_rescale_ref(x)
        assert bool(jnp.all(jnp.diff(y) > 0))
        assert float(jnp.max(jnp.abs(y))) < float(jnp.max(jnp.abs(x)))

    def test_zero_fixed_point(self):
        assert float(value_rescale_ref(jnp.float32(0.0))) == 0.0
        assert float(value_rescale_inv_ref(jnp.float32(0.0))) == 0.0
