"""hlo_cost parser: synthetic HLO snippets + real compiled graphs."""

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from compile import aot, hlo_cost, model

SYNTHETIC = """\
HloModule test

%fused_computation (param_0: f32[8,16], param_1: f32[16]) -> f32[8,16] {
  %param_0 = f32[8,16]{1,0} parameter(0)
  %param_1 = f32[16]{0} parameter(1)
  %broadcast.1 = f32[8,16]{1,0} broadcast(%param_1), dimensions={1}
  ROOT %add.1 = f32[8,16]{1,0} add(%param_0, %broadcast.1)
}

ENTRY %main (a: f32[8,32], w: f32[32,16], b: f32[16]) -> f32[8,16] {
  %a = f32[8,32]{1,0} parameter(0)
  %w = f32[32,16]{1,0} parameter(1)
  %b = f32[16]{0} parameter(2)
  %dot.1 = f32[8,16]{1,0} dot(%a, %w), lhs_contracting_dims={1}, rhs_contracting_dims={0}
  ROOT %fusion.1 = f32[8,16]{1,0} fusion(%dot.1, %b), kind=kLoop, calls=%fused_computation
}
"""


class TestSyntheticParse:
    def test_computations_found(self):
        comps = hlo_cost.parse_hlo_computations(SYNTHETIC)
        assert "__entry__" in comps
        assert "fused_computation" in comps
        assert len(comps["__entry__"]) == 5

    def test_dot_flops(self):
        comps = hlo_cost.parse_hlo_computations(SYNTHETIC)
        dot = next(i for i in comps["__entry__"] if i.opcode == "dot")
        # 2 * (8*16) * 32
        assert hlo_cost.instr_flops(dot, comps) == 2 * 8 * 16 * 32

    def test_operand_resolution(self):
        comps = hlo_cost.parse_hlo_computations(SYNTHETIC)
        dot = next(i for i in comps["__entry__"] if i.opcode == "dot")
        assert dot.in_bytes == (8 * 32 + 32 * 16) * 4
        assert dot.out_bytes == 8 * 16 * 4

    def test_fusion_flops_sum_body(self):
        comps = hlo_cost.parse_hlo_computations(SYNTHETIC)
        fusion = next(i for i in comps["__entry__"] if i.opcode == "fusion")
        # broadcast is movement (0) + add is 8*16.
        assert hlo_cost.instr_flops(fusion, comps) == 8 * 16

    def test_kernel_trace_excludes_parameters(self):
        kernels = hlo_cost.kernel_trace(SYNTHETIC)
        names = {k.opcode for k in kernels}
        assert "parameter" not in names
        assert {"dot", "fusion"} <= names

    def test_shape_helpers(self):
        s = hlo_cost.Shape("f32", (8, 16))
        assert s.elems == 128 and s.bytes == 512
        assert hlo_cost.Shape("pred", ()).bytes == 1
        assert hlo_cost.Shape("bf16", (4,)).bytes == 8


class TestRealGraphs:
    @pytest.fixture(scope="class")
    def inference_trace(self):
        cfg = model.AgentConfig(obs_size=6, obs_channels=2, num_actions=3,
                                conv1_filters=4, conv2_filters=8,
                                torso_dim=16, lstm_hidden=16, head_dim=8)
        params = model.init_params(jax.random.PRNGKey(0), cfg)
        fn, flat = aot.build_inference(params, cfg, 4)
        return aot.extract_trace(fn, flat, "test_infer")

    def test_trace_nonempty(self, inference_trace):
        assert inference_trace["summary"]["num_kernels"] > 3

    def test_parsed_flops_close_to_xla(self, inference_trace):
        xla = inference_trace["xla_cost_analysis_flops"]
        parsed = inference_trace["summary"]["total_flops"]
        if xla and xla > 0:
            # Same order of magnitude (transcendental weights differ).
            assert 0.5 * xla <= parsed <= 2.5 * xla

    def test_bytes_nonzero(self, inference_trace):
        assert inference_trace["summary"]["total_bytes_read"] > 0
        assert inference_trace["summary"]["total_bytes_written"] > 0

    def test_kernels_have_required_fields(self, inference_trace):
        for k in inference_trace["kernels"]:
            assert set(k) == {"name", "op", "flops", "bytes_read",
                              "bytes_written", "out_elems"}
            assert k["flops"] >= 0


class TestWhileTripCount:
    def test_default_is_one(self):
        instr = hlo_cost.Instr("w", "while", [], [], "body=%b", ["b"])
        assert hlo_cost._while_trip_count(instr) == 1

    def test_reads_backend_config(self):
        instr = hlo_cost.Instr(
            "w", "while", [], [],
            'body=%b, backend_config={"known_trip_count":{"n":"20"}}', ["b"])
        # Our regex targets trip_count=N or trip_count:"N" forms.
        assert hlo_cost._while_trip_count(instr) in (1, 20)
