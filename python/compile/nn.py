"""Minimal functional NN layer library for the L2 JAX model.

Deliberately dependency-free (no flax/haiku/optax in the build image):
parameters are nested dicts of jnp arrays, initializers are explicit, and
every layer is a pure function. The flattened parameter order (sorted by
dict key, depth-first — jax's dict pytree order) is the ABI between the
AOT artifacts and the Rust `ParamStore`; `flat_param_specs` below is the
single source of truth recorded into `manifest.json`.
"""

from __future__ import annotations

import math
from typing import Dict, List, Tuple

import jax
import jax.numpy as jnp

Params = Dict[str, object]


# ---------------------------------------------------------------------------
# Initializers
# ---------------------------------------------------------------------------

def glorot_uniform(key, shape, fan_in, fan_out, dtype=jnp.float32):
    limit = math.sqrt(6.0 / (fan_in + fan_out))
    return jax.random.uniform(key, shape, dtype, minval=-limit, maxval=limit)


def init_dense(key, in_dim: int, out_dim: int) -> Params:
    wkey, _ = jax.random.split(key)
    return {
        "w": glorot_uniform(wkey, (in_dim, out_dim), in_dim, out_dim),
        "b": jnp.zeros((out_dim,), jnp.float32),
    }


def init_conv(key, kh: int, kw: int, cin: int, cout: int) -> Params:
    fan_in, fan_out = kh * kw * cin, kh * kw * cout
    wkey, _ = jax.random.split(key)
    return {
        "w": glorot_uniform(wkey, (kh, kw, cin, cout), fan_in, fan_out),
        "b": jnp.zeros((cout,), jnp.float32),
    }


def init_lstm(key, in_dim: int, hidden: int) -> Params:
    """LSTM weights in the fused [i,f,g,o] layout the Pallas kernel expects."""
    kx, kh = jax.random.split(key)
    return {
        "wx": glorot_uniform(kx, (in_dim, 4 * hidden), in_dim, 4 * hidden),
        "wh": glorot_uniform(kh, (hidden, 4 * hidden), hidden, 4 * hidden),
        "b": jnp.zeros((4 * hidden,), jnp.float32),
    }


# ---------------------------------------------------------------------------
# Layers (pure functions)
# ---------------------------------------------------------------------------

def dense(p: Params, x):
    return x @ p["w"] + p["b"]


def conv2d(p: Params, x, stride: int = 1):
    """NHWC conv with SAME padding (HWIO kernel layout)."""
    y = jax.lax.conv_general_dilated(
        x,
        p["w"],
        window_strides=(stride, stride),
        padding="SAME",
        dimension_numbers=("NHWC", "HWIO", "NHWC"),
    )
    return y + p["b"]


def relu(x):
    return jax.nn.relu(x)


# ---------------------------------------------------------------------------
# Parameter ABI helpers
# ---------------------------------------------------------------------------

def flat_param_specs(params) -> List[Tuple[str, Tuple[int, ...], str]]:
    """[(dotted-path, shape, dtype)] in jax pytree flatten order.

    This order is what `aot.py` writes to manifest.json and what the Rust
    runtime uses to feed/collect parameter literals — keep deterministic.
    """
    flat, _ = jax.tree_util.tree_flatten_with_path(params)
    specs = []
    for path, leaf in flat:
        name = ".".join(str(getattr(k, "key", k)) for k in path)
        specs.append((name, tuple(leaf.shape), str(leaf.dtype)))
    return specs


def param_count(params) -> int:
    return sum(int(x.size) for x in jax.tree_util.tree_leaves(params))
