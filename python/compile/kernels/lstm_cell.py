"""Fused LSTM-cell Pallas kernel — the L1 compute hot-spot.

R2D2's recurrent core dominates both the inference and training graphs
(two [B,H]x[H,4H] matmuls plus four gate nonlinearities per timestep).
On the paper's V100 these run as separate cuBLAS + elementwise kernels;
re-expressed for a TPU-style memory hierarchy we fuse the whole cell so
the [B,4H] gate pre-activations never round-trip through HBM:

  * grid over batch tiles only; each program instance holds a
    [block_b, I] activation tile plus the full weight panels in VMEM.
  * both matmuls (x@Wx and h@Wh) accumulate in fp32 inside the kernel
    (``preferred_element_type``) so bf16 inputs keep MXU-friendly
    accumulation semantics.
  * gate split + sigmoid/tanh + state update are fused pointwise ops on
    the VMEM-resident tile.

VMEM budget (fp32): block_b*(I+9H) + 4H*(I+H+1) words. With the default
agent sizes (I=128, H=128, block_b=8) that is ~135 KiB — comfortably
under a TPU core's ~16 MiB VMEM; see EXPERIMENTS.md §Perf for the
footprint/utilization table across tile choices.

``interpret=True`` is mandatory here: the CPU PJRT plugin cannot execute
Mosaic custom-calls, and the whole library runs AOT HLO on CPU. The
kernel still exercises the real BlockSpec/grid machinery.
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

from .ref import FORGET_BIAS


def _lstm_cell_kernel(x_ref, h_ref, c_ref, wx_ref, wh_ref, b_ref,
                      h_out_ref, c_out_ref, *, hidden: int):
    """Kernel body: one [block_b, *] batch tile, full weight panels."""
    x = x_ref[...]
    h = h_ref[...]
    c = c_ref[...]
    # Accumulate in fp32 regardless of input dtype (MXU-style accumulation).
    gates = jnp.dot(x, wx_ref[...], preferred_element_type=jnp.float32)
    gates += jnp.dot(h, wh_ref[...], preferred_element_type=jnp.float32)
    gates += b_ref[...].astype(jnp.float32)

    i = gates[:, 0 * hidden : 1 * hidden]
    f = gates[:, 1 * hidden : 2 * hidden]
    g = gates[:, 2 * hidden : 3 * hidden]
    o = gates[:, 3 * hidden : 4 * hidden]

    c32 = c.astype(jnp.float32)
    c_new = jax.nn.sigmoid(f + FORGET_BIAS) * c32 + jax.nn.sigmoid(i) * jnp.tanh(g)
    h_new = jax.nn.sigmoid(o) * jnp.tanh(c_new)

    h_out_ref[...] = h_new.astype(h_out_ref.dtype)
    c_out_ref[...] = c_new.astype(c_out_ref.dtype)


def _lstm_cell_pallas(x, h, c, wx, wh, b, block_b: int):
    """One fused LSTM cell step via Pallas.

    Args:
      x:  [B, I]  input activations.
      h:  [B, H]  previous hidden state.
      c:  [B, H]  previous cell state.
      wx: [I, 4H] input->gates weights (gate order i,f,g,o).
      wh: [H, 4H] hidden->gates weights.
      b:  [4H]    gate biases.
      block_b: batch tile size (grid dimension). Batches that are not a
        multiple are zero-padded and sliced back, so any B >= 1 works.

    Returns:
      (h_new [B, H], c_new [B, H]) with the dtypes of (h, c).
    """
    batch, in_dim = x.shape
    hidden = h.shape[-1]
    assert wx.shape == (in_dim, 4 * hidden), (wx.shape, in_dim, hidden)
    assert wh.shape == (hidden, 4 * hidden)
    assert b.shape == (4 * hidden,)

    block_b = max(1, min(block_b, batch))
    padded = -(-batch // block_b) * block_b  # ceil to tile multiple
    if padded != batch:
        pad = [(0, padded - batch), (0, 0)]
        x, h, c = jnp.pad(x, pad), jnp.pad(h, pad), jnp.pad(c, pad)

    grid = (padded // block_b,)
    kernel = functools.partial(_lstm_cell_kernel, hidden=hidden)
    h_new, c_new = pl.pallas_call(
        kernel,
        grid=grid,
        in_specs=[
            pl.BlockSpec((block_b, in_dim), lambda i: (i, 0)),   # x tile
            pl.BlockSpec((block_b, hidden), lambda i: (i, 0)),   # h tile
            pl.BlockSpec((block_b, hidden), lambda i: (i, 0)),   # c tile
            pl.BlockSpec((in_dim, 4 * hidden), lambda i: (0, 0)),  # Wx panel
            pl.BlockSpec((hidden, 4 * hidden), lambda i: (0, 0)),  # Wh panel
            pl.BlockSpec((4 * hidden,), lambda i: (0,)),           # bias
        ],
        out_specs=[
            pl.BlockSpec((block_b, hidden), lambda i: (i, 0)),
            pl.BlockSpec((block_b, hidden), lambda i: (i, 0)),
        ],
        out_shape=[
            jax.ShapeDtypeStruct((padded, hidden), h.dtype),
            jax.ShapeDtypeStruct((padded, hidden), c.dtype),
        ],
        interpret=True,  # CPU PJRT cannot run Mosaic custom-calls.
    )(x, h, c, wx, wh, b)

    if padded != batch:
        h_new, c_new = h_new[:batch], c_new[:batch]
    return h_new, c_new


# Pallas bodies have no automatic reverse-mode rule; the backward pass is
# supplied via custom_vjp using the pure-jnp reference (same math — the
# oracle pytest asserts kernel == ref to float tolerance). The ref forward
# is rematerialized inside the vjp, which is also what a fused TPU kernel
# would do rather than spilling gate pre-activations to HBM.
@functools.partial(jax.custom_vjp, nondiff_argnums=(6,))
def _lstm_cell_cv(x, h, c, wx, wh, b, block_b):
    return _lstm_cell_pallas(x, h, c, wx, wh, b, block_b)


def _lstm_cell_fwd(x, h, c, wx, wh, b, block_b):
    out = _lstm_cell_pallas(x, h, c, wx, wh, b, block_b)
    return out, (x, h, c, wx, wh, b)


def _lstm_cell_bwd(block_b, residuals, cotangents):
    from .ref import lstm_cell_ref

    _, vjp = jax.vjp(lstm_cell_ref, *residuals)
    return vjp(cotangents)


_lstm_cell_cv.defvjp(_lstm_cell_fwd, _lstm_cell_bwd)


def lstm_cell(x, h, c, wx, wh, b, *, block_b: int = 8):
    """Fused LSTM cell: Pallas forward, reference-vjp backward (see above)."""
    return _lstm_cell_cv(x, h, c, wx, wh, b, block_b)


def lstm_vmem_bytes(block_b: int, in_dim: int, hidden: int,
                    bytes_per_el: int = 4) -> int:
    """Static VMEM footprint estimate for one program instance.

    Used by DESIGN.md / EXPERIMENTS.md §Perf tables and unit-tested against
    a hand computation; interpret-mode wallclock is NOT a TPU proxy, so
    tiles are chosen on this analytic model instead.
    """
    act = block_b * (in_dim + 2 * hidden)          # x, h, c tiles
    gates = block_b * 4 * hidden                   # fused gate tile (fp32)
    outs = block_b * 2 * hidden                    # h', c'
    weights = 4 * hidden * (in_dim + hidden + 1)   # Wx, Wh, b panels
    return (act + gates + outs + weights) * bytes_per_el
