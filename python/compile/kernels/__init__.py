"""L1 Pallas kernels (build-time only; lowered into the L2 HLO).

All kernels run in interpret mode so the AOT'd HLO executes on the CPU
PJRT plugin; real-TPU performance is analyzed statically (VMEM footprint,
MXU utilization) in DESIGN.md / EXPERIMENTS.md.
"""

from .dueling import dueling_head
from .lstm_cell import lstm_cell, lstm_vmem_bytes
from .ref import (
    FORGET_BIAS,
    GATE_ORDER,
    dueling_head_ref,
    lstm_cell_ref,
    value_rescale_inv_ref,
    value_rescale_ref,
)

__all__ = [
    "FORGET_BIAS",
    "GATE_ORDER",
    "dueling_head",
    "dueling_head_ref",
    "lstm_cell",
    "lstm_cell_ref",
    "lstm_vmem_bytes",
    "value_rescale_inv_ref",
    "value_rescale_ref",
]
