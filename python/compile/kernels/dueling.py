"""Fused dueling-head Pallas kernel.

The dueling aggregation q = v + a - mean(a) is a small pointwise+reduce
epilogue that XLA would otherwise emit as a separate fusion after the two
head matmuls; fusing it keeps the advantage tile in VMEM. The kernel also
demonstrates a reduction inside a Pallas body (mean over the action axis).

Like every kernel in this package it runs with interpret=True (CPU PJRT)
and is validated against ``ref.dueling_head_ref`` by pytest/hypothesis.
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl


def _dueling_kernel(v_ref, a_ref, q_ref):
    v = v_ref[...].astype(jnp.float32)          # [bb, 1]
    a = a_ref[...].astype(jnp.float32)          # [bb, A]
    mean_a = jnp.mean(a, axis=-1, keepdims=True)
    q_ref[...] = (v + a - mean_a).astype(q_ref.dtype)


def _dueling_pallas(value, advantage, block_b: int):
    """Dueling Q aggregation: q = v + a - mean_a(a).

    Args:
      value:     [B, 1] state-value stream.
      advantage: [B, A] advantage stream.
      block_b:   batch tile size.

    Returns:
      q: [B, A] with advantage's dtype.
    """
    batch, actions = advantage.shape
    assert value.shape == (batch, 1), (value.shape, batch)

    block_b = max(1, min(block_b, batch))
    padded = -(-batch // block_b) * block_b
    if padded != batch:
        pad = [(0, padded - batch), (0, 0)]
        value, advantage = jnp.pad(value, pad), jnp.pad(advantage, pad)

    q = pl.pallas_call(
        _dueling_kernel,
        grid=(padded // block_b,),
        in_specs=[
            pl.BlockSpec((block_b, 1), lambda i: (i, 0)),
            pl.BlockSpec((block_b, actions), lambda i: (i, 0)),
        ],
        out_specs=pl.BlockSpec((block_b, actions), lambda i: (i, 0)),
        out_shape=jax.ShapeDtypeStruct((padded, actions), advantage.dtype),
        interpret=True,
    )(value, advantage)

    if padded != batch:
        q = q[:batch]
    return q


# custom_vjp: Pallas forward, pure-jnp reference backward (same math; see
# lstm_cell.py for the rationale).
@functools.partial(jax.custom_vjp, nondiff_argnums=(2,))
def _dueling_cv(value, advantage, block_b):
    return _dueling_pallas(value, advantage, block_b)


def _dueling_fwd(value, advantage, block_b):
    return _dueling_pallas(value, advantage, block_b), (value, advantage)


def _dueling_bwd(block_b, residuals, g):
    from .ref import dueling_head_ref

    _, vjp = jax.vjp(dueling_head_ref, *residuals)
    return vjp(g)


_dueling_cv.defvjp(_dueling_fwd, _dueling_bwd)


def dueling_head(value, advantage, *, block_b: int = 32):
    """Fused dueling aggregation: Pallas forward, reference-vjp backward."""
    return _dueling_cv(value, advantage, block_b)
