"""Pure-jnp reference oracles for the Pallas kernels (L1 correctness).

Every Pallas kernel in this package has an exact pure-`jax.numpy`
counterpart here. pytest (with hypothesis shape/dtype sweeps) asserts
`assert_allclose(kernel(...), ref(...))` at build time; the kernels are
never trusted without the oracle.
"""

from __future__ import annotations

import jax
import jax.numpy as jnp

# Gate layout used across the library: [i, f, g, o] along the 4H axis.
GATE_ORDER = ("input", "forget", "cell", "output")

# Standard LSTM forget-gate bias (helps early training stability).
FORGET_BIAS = 1.0


def lstm_cell_ref(x, h, c, wx, wh, b):
    """One LSTM cell step.

    Args:
      x:  [B, I]  input activations.
      h:  [B, H]  previous hidden state.
      c:  [B, H]  previous cell state.
      wx: [I, 4H] input->gates weights (gate order i,f,g,o).
      wh: [H, 4H] hidden->gates weights.
      b:  [4H]    gate biases.

    Returns:
      (h_new [B, H], c_new [B, H])
    """
    gates = x @ wx + h @ wh + b
    hidden = h.shape[-1]
    i, f, g, o = (
        gates[..., 0 * hidden : 1 * hidden],
        gates[..., 1 * hidden : 2 * hidden],
        gates[..., 2 * hidden : 3 * hidden],
        gates[..., 3 * hidden : 4 * hidden],
    )
    c_new = jax.nn.sigmoid(f + FORGET_BIAS) * c + jax.nn.sigmoid(i) * jnp.tanh(g)
    h_new = jax.nn.sigmoid(o) * jnp.tanh(c_new)
    return h_new, c_new


def dueling_head_ref(value, advantage):
    """Dueling Q aggregation: q = v + a - mean_a(a).

    Args:
      value:     [B, 1] state-value stream.
      advantage: [B, A] advantage stream.

    Returns:
      q: [B, A]
    """
    return value + advantage - jnp.mean(advantage, axis=-1, keepdims=True)


def value_rescale_ref(x, eps=1e-3):
    """R2D2 invertible value rescaling h(x) = sign(x)(sqrt(|x|+1)-1) + eps*x."""
    return jnp.sign(x) * (jnp.sqrt(jnp.abs(x) + 1.0) - 1.0) + eps * x


def value_rescale_inv_ref(x, eps=1e-3):
    """Inverse of `value_rescale_ref` (closed form from the R2D2 paper)."""
    a = jnp.sqrt(1.0 + 4.0 * eps * (jnp.abs(x) + 1.0 + eps))
    return jnp.sign(x) * ((((a - 1.0) / (2.0 * eps)) ** 2) - 1.0)
