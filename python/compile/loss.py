"""R2D2 and V-trace (IMPALA) losses + the full AOT'd train steps.

R2D2 (Kapturowski et al., ICLR'19), as run by SEED RL and profiled by the
paper: recurrent double-Q learning over length-T sequences with
  * LSTM burn-in (stop-gradient prefix to refresh stale recurrent state),
  * n-step returns,
  * invertible value rescaling h / h^-1 instead of reward clipping,
  * per-sequence priorities  eta*max|td| + (1-eta)*mean|td|.

V-trace (Espeholt et al., ICML'18) is the off-policy actor-critic baseline
the paper contrasts architecturally (actor-side inference); implemented on
the same torso/LSTM so the two systems are compute-comparable.

Tensor-time convention in this file: sequences enter as [B, T, ...]
(Rust's replay layout) and are transposed to [T, B, ...] for lax.scan.
"""

from __future__ import annotations

import dataclasses
from typing import Tuple

import jax
import jax.numpy as jnp

from . import model, optim
from .kernels.ref import value_rescale_inv_ref as h_inv
from .kernels.ref import value_rescale_ref as h


@dataclasses.dataclass(frozen=True)
class R2d2Config:
    """Loss/optimizer hyper-parameters for the R2D2 learner."""

    burn_in: int = 5          # stop-gradient prefix steps
    unroll_len: int = 15      # trained steps (sequence length = burn_in+unroll)
    n_step: int = 3
    gamma: float = 0.997
    priority_eta: float = 0.9
    adam: optim.AdamConfig = dataclasses.field(default_factory=optim.AdamConfig)

    @property
    def seq_len(self) -> int:
        return self.burn_in + self.unroll_len


def _shift_time(x, k: int):
    """x[t] -> x[t+k] along axis 0, zero-padded at the tail. x: [T, ...]."""
    if k == 0:
        return x
    pad = jnp.zeros((k,) + x.shape[1:], x.dtype)
    return jnp.concatenate([x[k:], pad], axis=0)


def n_step_targets(q_online, q_target, actions, rewards, discounts,
                   n_step: int):
    """Rescaled n-step double-Q targets and TD errors.

    All inputs are time-major over the *training* window:
      q_online, q_target: [T, B, A]; actions: [T, B] (a_t taken at s_t);
      rewards, discounts: [T, B] (r_t, gamma*(1-done_t) after a_t).

    Returns (td_error [T, B], valid_mask [T]) where entries with
    t >= T - n_step are invalid (no bootstrap available in-window).
    """
    t_len = q_online.shape[0]
    q_sel = jnp.take_along_axis(q_online, actions[..., None], axis=-1)[..., 0]

    # Double-Q bootstrap value in un-rescaled space.
    a_star = jnp.argmax(q_online, axis=-1)
    boot = h_inv(jnp.take_along_axis(q_target, a_star[..., None], -1)[..., 0])

    ret = jnp.zeros_like(rewards)
    cum = jnp.ones_like(discounts)
    for k in range(n_step):
        ret = ret + cum * _shift_time(rewards, k)
        cum = cum * _shift_time(discounts, k)
    ret = ret + cum * _shift_time(boot, n_step)

    td = h(ret) - q_sel
    valid = (jnp.arange(t_len) < t_len - n_step).astype(td.dtype)
    return td * valid[:, None], valid


def r2d2_loss(params, target_params, obs, actions, rewards, discounts,
              h0, c0, agent_cfg: model.AgentConfig, cfg: R2d2Config):
    """Scalar loss + per-sequence priorities.

    Args (batch-major, B sequences of length T = burn_in + unroll_len):
      obs:       [B, T, S, S, C] float32 in [0, 1].
      actions:   [B, T] int32.
      rewards:   [B, T] float32.
      discounts: [B, T] float32 (gamma * (1 - done)).
      h0, c0:    [B, H] recurrent state stored at sequence start.

    Returns (loss, (priorities [B], mean_abs_td)).
    """
    obs_t = jnp.transpose(obs, (1, 0) + tuple(range(2, obs.ndim)))  # [T,B,...]

    # Burn-in: refresh recurrent state, no gradient.
    if cfg.burn_in > 0:
        _, (h_b, c_b) = model.unroll(params, h0, c0, obs_t[: cfg.burn_in],
                                     agent_cfg)
        h_b, c_b = jax.lax.stop_gradient(h_b), jax.lax.stop_gradient(c_b)
    else:
        h_b, c_b = h0, c0

    train_obs = obs_t[cfg.burn_in:]
    q_online, _ = model.unroll(params, h_b, c_b, train_obs, agent_cfg)
    q_target, _ = model.unroll(target_params, h_b, c_b, train_obs, agent_cfg)
    q_target = jax.lax.stop_gradient(q_target)

    acts = jnp.transpose(actions, (1, 0))[cfg.burn_in:]
    rews = jnp.transpose(rewards, (1, 0))[cfg.burn_in:]
    disc = jnp.transpose(discounts, (1, 0))[cfg.burn_in:]

    td, valid = n_step_targets(q_online, q_target, acts, rews, disc,
                               cfg.n_step)
    n_valid = jnp.maximum(jnp.sum(valid), 1.0)

    loss = 0.5 * jnp.sum(jnp.square(td)) / (n_valid * td.shape[1])

    abs_td = jnp.abs(td)                                   # [T, B]
    max_td = jnp.max(abs_td, axis=0)
    mean_td = jnp.sum(abs_td, axis=0) / n_valid
    priorities = cfg.priority_eta * max_td + (1 - cfg.priority_eta) * mean_td
    return loss, (priorities, jnp.sum(abs_td) / (n_valid * td.shape[1]))


def r2d2_train_step(params, target_params, opt_state, obs, actions, rewards,
                    discounts, h0, c0, agent_cfg: model.AgentConfig,
                    cfg: R2d2Config):
    """Full learner step: loss grad + Adam. AOT'd as train.hlo.txt.

    Returns (new_params, new_opt_state, loss, priorities, grad_norm).
    """
    (loss, (priorities, _)), grads = jax.value_and_grad(
        r2d2_loss, has_aux=True)(params, target_params, obs, actions,
                                 rewards, discounts, h0, c0, agent_cfg, cfg)
    new_params, new_opt, gnorm = optim.adam_update(params, grads, opt_state,
                                                   cfg.adam)
    return new_params, new_opt, loss, priorities, gnorm


# ---------------------------------------------------------------------------
# V-trace (IMPALA baseline)
# ---------------------------------------------------------------------------


@dataclasses.dataclass(frozen=True)
class VtraceConfig:
    unroll_len: int = 20      # T; transitions trained: T-1
    gamma: float = 0.99
    rho_clip: float = 1.0
    c_clip: float = 1.0
    baseline_cost: float = 0.5
    entropy_cost: float = 0.01
    adam: optim.AdamConfig = dataclasses.field(default_factory=optim.AdamConfig)


def vtrace_returns(values, rewards, discounts, rhos, cs, bootstrap):
    """V-trace value targets vs (Espeholt et al., eq. 1), time-major.

    values, rewards, discounts, rhos, cs: [T, B]; bootstrap: [B].
    Returns vs: [T, B].
    """
    deltas = rhos * (rewards + discounts * jnp.concatenate(
        [values[1:], bootstrap[None]], axis=0) - values)

    def backward(acc, xs):
        delta_t, disc_t, c_t = xs
        acc = delta_t + disc_t * c_t * acc
        return acc, acc

    _, dvs = jax.lax.scan(backward, jnp.zeros_like(bootstrap),
                          (deltas, discounts, cs), reverse=True)
    return values + dvs


def vtrace_loss(params, obs, actions, rewards, discounts, behavior_logits,
                h0, c0, agent_cfg: model.AgentConfig, cfg: VtraceConfig):
    """IMPALA actor-critic loss over [B, T] trajectories (last step = boot)."""
    obs_t = jnp.transpose(obs, (1, 0) + tuple(range(2, obs.ndim)))
    logits, values, _ = model.vtrace_unroll(params, h0, c0, obs_t, agent_cfg)

    acts = jnp.transpose(actions, (1, 0))[:-1]          # [T-1, B]
    rews = jnp.transpose(rewards, (1, 0))[:-1]
    disc = jnp.transpose(discounts, (1, 0))[:-1]
    blogits = jnp.transpose(behavior_logits, (1, 0, 2))[:-1]  # [T-1, B, A]

    logp = jax.nn.log_softmax(logits[:-1])
    blogp = jax.nn.log_softmax(blogits)
    logp_a = jnp.take_along_axis(logp, acts[..., None], -1)[..., 0]
    blogp_a = jnp.take_along_axis(blogp, acts[..., None], -1)[..., 0]

    log_rho = logp_a - blogp_a
    rhos = jnp.minimum(jnp.exp(log_rho), cfg.rho_clip)
    cs = jnp.minimum(jnp.exp(log_rho), cfg.c_clip)

    v = values[:-1]
    vs = jax.lax.stop_gradient(
        vtrace_returns(jax.lax.stop_gradient(v), rews, disc, rhos, cs,
                       jax.lax.stop_gradient(values[-1])))
    vs_next = jnp.concatenate([vs[1:], values[-1:]], axis=0)
    pg_adv = jax.lax.stop_gradient(rhos * (rews + disc * vs_next - v))

    pg_loss = -jnp.mean(logp_a * pg_adv)
    baseline_loss = 0.5 * jnp.mean(jnp.square(vs - v))
    entropy = -jnp.mean(jnp.sum(jax.nn.softmax(logits[:-1]) * logp, axis=-1))

    total = (pg_loss + cfg.baseline_cost * baseline_loss
             - cfg.entropy_cost * entropy)
    return total, (pg_loss, baseline_loss, entropy)


def vtrace_train_step(params, opt_state, obs, actions, rewards, discounts,
                      behavior_logits, h0, c0, agent_cfg: model.AgentConfig,
                      cfg: VtraceConfig):
    """AOT'd as vtrace_train.hlo.txt. Returns (params', opt', loss, gnorm)."""
    (loss, _), grads = jax.value_and_grad(vtrace_loss, has_aux=True)(
        params, obs, actions, rewards, discounts, behavior_logits, h0, c0,
        agent_cfg, cfg)
    new_params, new_opt, gnorm = optim.adam_update(params, grads, opt_state,
                                                   cfg.adam)
    return new_params, new_opt, loss, gnorm
