"""Adam optimizer, dependency-free (no optax in the build image).

Optimizer state is `(step: i32[], m: pytree, v: pytree)`; all three travel
through the AOT train-step artifact as flat literals, so the Rust learner
just feeds the previous outputs back in (donated buffers — see aot.py).
"""

from __future__ import annotations

import dataclasses

import jax
import jax.numpy as jnp


@dataclasses.dataclass(frozen=True)
class AdamConfig:
    lr: float = 1e-3
    b1: float = 0.9
    b2: float = 0.999
    eps: float = 1e-8
    grad_clip: float = 40.0  # global-norm clip (R2D2 uses 40)


def init_opt_state(params):
    zeros = jax.tree_util.tree_map(jnp.zeros_like, params)
    return (jnp.zeros((), jnp.int32), zeros,
            jax.tree_util.tree_map(jnp.zeros_like, params))


def clip_by_global_norm(grads, max_norm: float):
    """Scale grads so their global l2 norm is at most max_norm."""
    leaves = jax.tree_util.tree_leaves(grads)
    gnorm = jnp.sqrt(sum(jnp.sum(jnp.square(g)) for g in leaves))
    scale = jnp.minimum(1.0, max_norm / (gnorm + 1e-12))
    return jax.tree_util.tree_map(lambda g: g * scale, grads), gnorm


def adam_update(params, grads, opt_state, cfg: AdamConfig):
    """One Adam step with bias correction and global-norm clipping.

    Returns (new_params, new_opt_state, grad_norm).
    """
    step, m, v = opt_state
    grads, gnorm = clip_by_global_norm(grads, cfg.grad_clip)
    step = step + 1
    t = step.astype(jnp.float32)
    bc1 = 1.0 - cfg.b1 ** t
    bc2 = 1.0 - cfg.b2 ** t

    m = jax.tree_util.tree_map(lambda mi, g: cfg.b1 * mi + (1 - cfg.b1) * g,
                               m, grads)
    v = jax.tree_util.tree_map(
        lambda vi, g: cfg.b2 * vi + (1 - cfg.b2) * jnp.square(g), v, grads)

    def upd(p, mi, vi):
        mhat = mi / bc1
        vhat = vi / bc2
        return p - cfg.lr * mhat / (jnp.sqrt(vhat) + cfg.eps)

    new_params = jax.tree_util.tree_map(upd, params, m, v)
    return new_params, (step, m, v), gnorm
