"""AOT lowering: JAX graphs -> HLO *text* artifacts + manifest + kernel trace.

Run once by `make artifacts`; the Rust binary is self-contained afterwards.

Interchange format is HLO TEXT, not serialized HloModuleProto: the image's
xla_extension 0.5.1 rejects jax>=0.5 protos (64-bit instruction ids), but
the text parser reassigns ids and round-trips cleanly. See
/opt/xla-example/README.md. Lowering path:

    jax.jit(fn).lower(*specs)
      -> compiler_ir("stablehlo")
      -> xla_client mlir_module_to_xla_computation (return_tuple=True)
      -> .as_hlo_text()

Artifacts written to --out-dir (default ../artifacts):
  infer_b{B}.hlo.txt        central-inference forward, B in --infer-batches
  train.hlo.txt             R2D2 learner step (loss + Adam, donated state)
  vtrace_train.hlo.txt      IMPALA baseline learner step
  init_params.npz           initial parameter/optimizer literals (seeded)
  kernel_trace.json         per-kernel FLOPs/bytes for rlarch::simarch
  manifest.json             parameter ABI + artifact I/O signatures
"""

from __future__ import annotations

import argparse
import json
import os
import time

import jax
import jax.numpy as jnp
import numpy as np
from jax._src.lib import xla_client as xc

from . import hlo_cost, loss, model, nn, optim

DEFAULT_SEED = 20200831  # EMC^2 2020 workshop date.


# ---------------------------------------------------------------------------
# Lowering helpers
# ---------------------------------------------------------------------------

def to_hlo_text(lowered) -> str:
    """StableHLO -> XlaComputation -> HLO text (see module docstring)."""
    mlir_mod = lowered.compiler_ir("stablehlo")
    comp = xc._xla.mlir.mlir_module_to_xla_computation(
        str(mlir_mod), use_tuple_args=False, return_tuple=True)
    return comp.as_hlo_text()


def spec_of(x) -> jax.ShapeDtypeStruct:
    return jax.ShapeDtypeStruct(x.shape, x.dtype)


TENSOR_BUNDLE_MAGIC = b"RLTENSORBUNDLE1\n"


def write_tensor_bundle(path: str, named: "list[tuple[str, np.ndarray]]"):
    """Self-describing tensor container the Rust runtime can read without
    numpy: magic, u64-LE header length, JSON header
    [{name, shape, dtype, offset, nbytes}], raw little-endian payload."""
    header = []
    payload = bytearray()
    for name, arr in named:
        arr = np.ascontiguousarray(arr)
        raw = arr.tobytes()
        header.append({
            "name": name,
            "shape": list(arr.shape),
            "dtype": arr.dtype.name,
            "offset": len(payload),
            "nbytes": len(raw),
        })
        payload.extend(raw)
    hjson = json.dumps(header).encode()
    with open(path, "wb") as f:
        f.write(TENSOR_BUNDLE_MAGIC)
        f.write(len(hjson).to_bytes(8, "little"))
        f.write(hjson)
        f.write(bytes(payload))


def _sig(tree) -> list:
    """JSON signature ([{name, shape, dtype}]) of a flat arg list."""
    out = []
    for i, leaf in enumerate(jax.tree_util.tree_leaves(tree)):
        out.append({"index": i, "shape": list(leaf.shape),
                    "dtype": str(leaf.dtype)})
    return out


# ---------------------------------------------------------------------------
# Artifact builders — each returns (fn_flat, example_flat_args, meta)
# ---------------------------------------------------------------------------

def build_inference(params, agent_cfg: model.AgentConfig, batch: int,
                    static_unroll_trace: bool = False):
    """Central-inference graph over a [B, S, S, C] observation batch."""
    _, treedef = jax.tree_util.tree_flatten(params)
    n_params = treedef.num_leaves

    def fn(*flat):
        p = jax.tree_util.tree_unflatten(treedef, flat[:n_params])
        h, c, obs = flat[n_params:]
        q, h2, c2 = model.apply_inference(p, h, c, obs, agent_cfg)
        return q, h2, c2

    h0, c0 = model.initial_state(batch, agent_cfg)
    obs = jnp.zeros((batch,) + agent_cfg.obs_shape, jnp.float32)
    flat_args = jax.tree_util.tree_leaves(params) + [h0, c0, obs]
    return fn, flat_args


def build_train(params, opt_state, agent_cfg: model.AgentConfig,
                cfg: loss.R2d2Config, batch: int, trace_unroll: bool = False):
    """R2D2 learner step. Flat ABI:

      inputs:  [params..., target_params..., opt_step, opt_m..., opt_v...,
                obs, actions, rewards, discounts, h0, c0]
      outputs: (params'..., opt_step', opt_m'..., opt_v'..., loss,
                priorities, grad_norm)
    """
    _, p_def = jax.tree_util.tree_flatten(params)
    n_p = p_def.num_leaves
    _, o_def = jax.tree_util.tree_flatten(opt_state)
    n_o = o_def.num_leaves

    unroll_fn = model.unroll_static if trace_unroll else model.unroll

    def fn(*flat):
        p = jax.tree_util.tree_unflatten(p_def, flat[:n_p])
        tp = jax.tree_util.tree_unflatten(p_def, flat[n_p: 2 * n_p])
        opt = jax.tree_util.tree_unflatten(o_def,
                                           flat[2 * n_p: 2 * n_p + n_o])
        obs, actions, rewards, discounts, h0, c0 = flat[2 * n_p + n_o:]
        orig_unroll = model.unroll
        model.unroll = unroll_fn
        try:
            new_p, new_opt, l, prio, gnorm = loss.r2d2_train_step(
                p, tp, opt, obs, actions, rewards, discounts, h0, c0,
                agent_cfg, cfg)
        finally:
            model.unroll = orig_unroll
        return (tuple(jax.tree_util.tree_leaves(new_p)) +
                tuple(jax.tree_util.tree_leaves(new_opt)) +
                (l, prio, gnorm))

    t = cfg.seq_len
    obs = jnp.zeros((batch, t) + agent_cfg.obs_shape, jnp.float32)
    actions = jnp.zeros((batch, t), jnp.int32)
    rewards = jnp.zeros((batch, t), jnp.float32)
    discounts = jnp.zeros((batch, t), jnp.float32)
    h0, c0 = model.initial_state(batch, agent_cfg)
    flat_args = (jax.tree_util.tree_leaves(params) * 2 +
                 jax.tree_util.tree_leaves(opt_state) +
                 [obs, actions, rewards, discounts, h0, c0])
    return fn, flat_args


def build_vtrace_train(vparams, vopt, agent_cfg: model.AgentConfig,
                       cfg: loss.VtraceConfig, batch: int):
    """IMPALA learner step. Flat ABI mirrors build_train (no target net)."""
    _, p_def = jax.tree_util.tree_flatten(vparams)
    n_p = p_def.num_leaves
    _, o_def = jax.tree_util.tree_flatten(vopt)
    n_o = o_def.num_leaves

    def fn(*flat):
        p = jax.tree_util.tree_unflatten(p_def, flat[:n_p])
        opt = jax.tree_util.tree_unflatten(o_def, flat[n_p: n_p + n_o])
        obs, actions, rewards, discounts, blogits, h0, c0 = flat[n_p + n_o:]
        new_p, new_opt, l, gnorm = loss.vtrace_train_step(
            p, opt, obs, actions, rewards, discounts, blogits, h0, c0,
            agent_cfg, cfg)
        return (tuple(jax.tree_util.tree_leaves(new_p)) +
                tuple(jax.tree_util.tree_leaves(new_opt)) + (l, gnorm))

    t = cfg.unroll_len
    obs = jnp.zeros((batch, t) + agent_cfg.obs_shape, jnp.float32)
    actions = jnp.zeros((batch, t), jnp.int32)
    rewards = jnp.zeros((batch, t), jnp.float32)
    discounts = jnp.zeros((batch, t), jnp.float32)
    blogits = jnp.zeros((batch, t, agent_cfg.num_actions), jnp.float32)
    h0, c0 = model.initial_state(batch, agent_cfg)
    flat_args = (jax.tree_util.tree_leaves(vparams) +
                 jax.tree_util.tree_leaves(vopt) +
                 [obs, actions, rewards, discounts, blogits, h0, c0])
    return fn, flat_args


# ---------------------------------------------------------------------------
# Kernel trace extraction
# ---------------------------------------------------------------------------

def extract_trace(fn, flat_args, name: str) -> dict:
    """Compile with XLA:CPU, parse optimized HLO into a kernel trace."""
    specs = [spec_of(a) for a in flat_args]
    compiled = jax.jit(fn).lower(*specs).compile()
    opt_hlo = compiled.as_text()
    kernels = hlo_cost.kernel_trace(opt_hlo)
    summary = hlo_cost.trace_summary(kernels)
    # Cross-check against XLA's own analysis when available.
    xla_flops = None
    try:
        ca = compiled.cost_analysis()
        if isinstance(ca, (list, tuple)):
            ca = ca[0]
        xla_flops = float(ca.get("flops", -1.0))
    except Exception:
        pass
    return {
        "artifact": name,
        "kernels": [k.to_json() for k in kernels],
        "summary": summary,
        "xla_cost_analysis_flops": xla_flops,
    }


# ---------------------------------------------------------------------------
# main
# ---------------------------------------------------------------------------

def main() -> None:
    ap = argparse.ArgumentParser(description=__doc__)
    ap.add_argument("--out-dir", default=os.path.join("..", "artifacts"))
    ap.add_argument("--seed", type=int, default=DEFAULT_SEED)
    ap.add_argument("--infer-batches", type=int, nargs="+",
                    default=[1, 8, 32, 64])
    ap.add_argument("--train-batch", type=int, default=16)
    ap.add_argument("--vtrace-batch", type=int, default=16)
    ap.add_argument("--obs-size", type=int, default=10)
    ap.add_argument("--obs-channels", type=int, default=4)
    ap.add_argument("--num-actions", type=int, default=4)
    ap.add_argument("--lstm-hidden", type=int, default=128)
    ap.add_argument("--torso-dim", type=int, default=128)
    ap.add_argument("--burn-in", type=int, default=5)
    ap.add_argument("--unroll-len", type=int, default=15)
    ap.add_argument("--n-step", type=int, default=3)
    ap.add_argument("--gamma", type=float, default=0.997)
    ap.add_argument("--lr", type=float, default=1e-3)
    ap.add_argument("--skip-vtrace", action="store_true")
    ap.add_argument("--scan-train", action="store_true",
                    help="lower the train step with lax.scan instead of "
                         "the (faster-running) static unroll")
    ap.add_argument("--skip-trace", action="store_true",
                    help="skip kernel_trace.json (slow: compiles the "
                         "statically-unrolled graphs)")
    ap.add_argument("--skip-paper-trace", action="store_true",
                    help="skip the Atari-scale R2D2 trace extraction")
    ap.add_argument("--paper-unroll", type=int, default=40,
                    help="timesteps in the paper-scale trace graph")
    ap.add_argument("--paper-train-batch", type=int, default=64)
    args = ap.parse_args()

    agent_cfg = model.AgentConfig(
        obs_size=args.obs_size, obs_channels=args.obs_channels,
        num_actions=args.num_actions, lstm_hidden=args.lstm_hidden,
        torso_dim=args.torso_dim)
    r2d2_cfg = loss.R2d2Config(
        burn_in=args.burn_in, unroll_len=args.unroll_len,
        n_step=args.n_step, gamma=args.gamma,
        adam=optim.AdamConfig(lr=args.lr))
    vtrace_cfg = loss.VtraceConfig(unroll_len=args.unroll_len,
                                   adam=optim.AdamConfig(lr=args.lr))

    os.makedirs(args.out_dir, exist_ok=True)
    key = jax.random.PRNGKey(args.seed)
    pkey, vkey = jax.random.split(key)
    params = model.init_params(pkey, agent_cfg)
    opt_state = optim.init_opt_state(params)
    vparams = model.init_vtrace_params(vkey, agent_cfg)
    vopt = optim.init_opt_state(vparams)

    manifest = {
        "seed": args.seed,
        "agent": {
            "obs_size": agent_cfg.obs_size,
            "obs_channels": agent_cfg.obs_channels,
            "num_actions": agent_cfg.num_actions,
            "lstm_hidden": agent_cfg.lstm_hidden,
            "torso_dim": agent_cfg.torso_dim,
            "param_count": nn.param_count(params),
        },
        "r2d2": {
            "burn_in": r2d2_cfg.burn_in,
            "unroll_len": r2d2_cfg.unroll_len,
            "seq_len": r2d2_cfg.seq_len,
            "n_step": r2d2_cfg.n_step,
            "gamma": r2d2_cfg.gamma,
            "train_batch": args.train_batch,
            "lr": args.lr,
        },
        "vtrace": {
            "unroll_len": vtrace_cfg.unroll_len,
            "batch": args.vtrace_batch,
        },
        "param_specs": [
            {"name": n, "shape": list(s), "dtype": d}
            for n, s, d in nn.flat_param_specs(params)
        ],
        "vtrace_param_specs": [
            {"name": n, "shape": list(s), "dtype": d}
            for n, s, d in nn.flat_param_specs(vparams)
        ],
        "artifacts": {},
    }

    traces = []

    def emit(name: str, fn, flat_args, trace: bool = False):
        t0 = time.time()
        specs = [spec_of(a) for a in flat_args]
        lowered = jax.jit(fn).lower(*specs)
        text = to_hlo_text(lowered)
        path = os.path.join(args.out_dir, name + ".hlo.txt")
        with open(path, "w") as f:
            f.write(text)
        manifest["artifacts"][name] = {
            "path": os.path.basename(path),
            "inputs": _sig(flat_args),
            "lower_seconds": round(time.time() - t0, 2),
        }
        print(f"[aot] {name}: {len(text)} chars, "
              f"{len(flat_args)} inputs, {time.time() - t0:.1f}s")
        if trace and not args.skip_trace:
            t0 = time.time()
            traces.append(extract_trace(fn, flat_args, name))
            print(f"[aot] {name}: trace ({time.time() - t0:.1f}s)")

    # Inference graphs (one per batcher size).
    for b in args.infer_batches:
        fn, flat = build_inference(params, agent_cfg, b)
        emit(f"infer_b{b}", fn, flat, trace=(b == max(args.infer_batches)))

    # R2D2 learner step. Runtime artifact uses the statically-unrolled
    # graph: XLA fuses across timesteps, measured 2.2x faster than the
    # lax.scan lowering at T=20 (EXPERIMENTS.md §Perf L2); scan remains
    # available via --scan-train for compile-time-sensitive builds.
    fn, flat = build_train(params, opt_state, agent_cfg, r2d2_cfg,
                           args.train_batch,
                           trace_unroll=not args.scan_train)
    emit("train", fn, flat)

    # Kernel trace from the statically-unrolled learner graph (per-step
    # kernels visible; see model.unroll_static).
    if not args.skip_trace:
        tfn, tflat = build_train(params, opt_state, agent_cfg, r2d2_cfg,
                                 args.train_batch, trace_unroll=True)
        t0 = time.time()
        traces.append(extract_trace(tfn, tflat, "train_unrolled"))
        print(f"[aot] train_unrolled trace ({time.time() - t0:.1f}s)")

    # Paper-scale traces: SEED-RL's R2D2 is Atari-sized (84x84x4 obs,
    # stride-4/2 conv stack, LSTM 512, 18 actions, ~6.5M params). We do
    # not execute this graph on the CPU testbed — we lower it (statically
    # unrolled, unoptimized HLO: one op per kernel launch, like the
    # largely-unfused TF1 graph the paper profiled) and extract the
    # kernel trace for the simulator's Fig. 2 / Fig. 4 experiments.
    if not args.skip_trace and not args.skip_paper_trace:
        t0 = time.time()
        pcfg = model.AgentConfig(
            obs_size=84, obs_channels=4, num_actions=18,
            conv1_filters=32, conv2_filters=64,
            conv1_stride=4, conv2_stride=2,
            torso_dim=512, lstm_hidden=512, head_dim=512)
        pr2d2 = loss.R2d2Config(burn_in=0, unroll_len=args.paper_unroll,
                                n_step=5, adam=optim.AdamConfig(lr=args.lr))
        pkey2, _ = jax.random.split(pkey)
        pparams = model.init_params(pkey2, pcfg)
        popt = optim.init_opt_state(pparams)

        def unoptimized_trace(fn, flat, name):
            lowered = jax.jit(fn).lower(*[spec_of(a) for a in flat])
            text = to_hlo_text(lowered)
            kernels = hlo_cost.kernel_trace(text, coalesce=True)
            return {
                "artifact": name,
                "kernels": [k.to_json() for k in kernels],
                "summary": hlo_cost.trace_summary(kernels),
                "xla_cost_analysis_flops": None,
            }

        tfn, tflat = build_train(pparams, popt, pcfg, pr2d2,
                                 args.paper_train_batch, trace_unroll=True)
        traces.append(unoptimized_trace(tfn, tflat, "train_paper_scale"))
        ifn, iflat = build_inference(pparams, pcfg, 64)
        traces.append(unoptimized_trace(ifn, iflat, "infer_paper_scale"))
        print(f"[aot] paper-scale traces ({time.time() - t0:.1f}s, "
              f"{nn.param_count(pparams)} params)")

    if not args.skip_vtrace:
        fn, flat = build_vtrace_train(vparams, vopt, agent_cfg, vtrace_cfg,
                                      args.vtrace_batch)
        emit("vtrace_train", fn, flat)

    # Initial literals for the Rust ParamStore.
    flat_p = jax.tree_util.tree_leaves(params)
    flat_o = jax.tree_util.tree_leaves(opt_state)
    flat_vp = jax.tree_util.tree_leaves(vparams)
    flat_vo = jax.tree_util.tree_leaves(vopt)
    write_tensor_bundle(
        os.path.join(args.out_dir, "init_params.bin"),
        [(f"p{i}", np.asarray(x)) for i, x in enumerate(flat_p)]
        + [(f"o{i}", np.asarray(x)) for i, x in enumerate(flat_o)]
        + [(f"vp{i}", np.asarray(x)) for i, x in enumerate(flat_vp)]
        + [(f"vo{i}", np.asarray(x)) for i, x in enumerate(flat_vo)],
    )
    manifest["init"] = {
        "params": len(flat_p), "opt": len(flat_o),
        "vtrace_params": len(flat_vp), "vtrace_opt": len(flat_vo),
    }

    with open(os.path.join(args.out_dir, "kernel_trace.json"), "w") as f:
        json.dump({"traces": traces}, f, indent=1)
    with open(os.path.join(args.out_dir, "manifest.json"), "w") as f:
        json.dump(manifest, f, indent=1)
    print(f"[aot] wrote {len(manifest['artifacts'])} artifacts + manifest + "
          f"trace to {args.out_dir}")


if __name__ == "__main__":
    main()
