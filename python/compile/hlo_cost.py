"""HLO-text cost analyzer: turns compiled HLO into a GPU kernel trace.

The paper's Fig. 2 methodology feeds an operator trace of SEED-RL's R2D2
graphs into NVArchSim and idealizes memory-system components one by one.
We reproduce the trace-extraction half here: parse the XLA-*optimized*
HLO text of our own train/inference graphs, cost each instruction
(FLOPs, bytes read, bytes written, output parallelism), approximate
kernel launches (each non-trivial top-level instruction of the entry
computation = one kernel; `fusion` instructions sum their fused bodies),
and emit `artifacts/kernel_trace.json` for `rlarch::simarch`.

Parsing strategy: optimized HLO prints operands as bare `%name`
references, so we run two passes — (1) collect every instruction's
declared output shape into a global name->shape table (instruction names
are unique module-wide), (2) resolve operand shapes through the table.
The parser is deliberately tolerant: anything it cannot understand
degrades to a zero-FLOP bytes-only kernel rather than failing, and the
aggregate is cross-checked against XLA's own `cost_analysis()` (recorded
side-by-side in the JSON; asserted within a factor by pytest).
"""

from __future__ import annotations

import dataclasses
import re
from typing import Dict, List, Optional, Tuple

_DTYPE_BYTES = {
    "pred": 1, "s8": 1, "u8": 1, "s16": 2, "u16": 2, "bf16": 2, "f16": 2,
    "s32": 4, "u32": 4, "f32": 4, "s64": 8, "u64": 8, "f64": 8,
}

# e.g. f32[16,128]{1,0}  /  pred[]  /  s32[4]
_SHAPE_RE = re.compile(r"\b([a-z]+\d*)\[([\d,]*)\](?:\{[\d,]*\})?")

# Optimized HLO prefixes names with '%'; unoptimized (as_hlo_text) does not.
_INSTR_HEAD_RE = re.compile(r"^\s*(?:ROOT\s+)?%?([\w.\-]+)\s*=\s*(.*)$")

# "ENTRY %main (a: f32[2]) -> f32[2] {"  (optimized)  or
# "ENTRY main.12 {" / "relu.1 {"          (unoptimized)
_COMP_HEADER_RE = re.compile(
    r"^(ENTRY\s+)?%?([\w.\-]+)\s*\((.*)\)\s*->\s*(.*?)\s*\{\s*$")
_COMP_HEADER_BARE_RE = re.compile(r"^(ENTRY\s+)?%?([\w.\-]+)\s*\{\s*$")

_IDENT_RE = re.compile(r"^%?([A-Za-z_][\w.\-]*)$")

# Ops that never become standalone GPU kernels (pure data-movement
# bookkeeping XLA resolves to aliasing / no-ops).
_FREE_OPS = {
    "parameter", "constant", "tuple", "get-tuple-element", "bitcast",
    "bitcast-convert", "reshape", "after-all", "iota", "partition-id",
    "replica-id", "get-dimension-size",
}

# Transcendental-ish elementwise ops (weighted > 1 FLOP/element, roughly
# matching XLA's cost analysis weights for CPU/GPU SFU throughput).
_TRANSCENDENTAL = {
    "exponential": 4, "log": 4, "tanh": 6, "logistic": 6, "rsqrt": 2,
    "sqrt": 2, "power": 6, "divide": 2, "sine": 4, "cosine": 4,
    "exponential-minus-one": 4, "atan2": 8,
}

# Data-movement / control ops: 0 math FLOPs, bytes dominate.
_MOVEMENT_OPS = {
    "select-and-scatter", "scatter", "gather", "dynamic-slice",
    "dynamic-update-slice", "slice", "concatenate", "pad", "broadcast",
    "transpose", "copy", "copy-start", "copy-done", "convert", "select",
    "compare", "rng", "rng-bit-generator", "sort", "custom-call",
    "all-reduce", "all-gather", "reverse", "clamp", "and", "or", "not",
    "xor", "shift-left", "shift-right-logical", "shift-right-arithmetic",
}


@dataclasses.dataclass
class Shape:
    dtype: str
    dims: Tuple[int, ...]

    @property
    def elems(self) -> int:
        n = 1
        for d in self.dims:
            n *= d
        return n

    @property
    def bytes(self) -> int:
        return self.elems * _DTYPE_BYTES.get(self.dtype, 4)


@dataclasses.dataclass
class Instr:
    name: str
    opcode: str
    out: List[Shape]
    operand_names: List[str]
    attrs: str
    called: List[str]
    operands: List[Shape] = dataclasses.field(default_factory=list)

    @property
    def out_bytes(self) -> int:
        return sum(s.bytes for s in self.out)

    @property
    def in_bytes(self) -> int:
        return sum(s.bytes for s in self.operands)

    @property
    def out_elems(self) -> int:
        return sum(s.elems for s in self.out)


@dataclasses.dataclass
class KernelCost:
    """One modeled GPU kernel launch (the unit `simarch::gpu` consumes)."""

    name: str
    opcode: str
    flops: float
    bytes_read: int
    bytes_written: int
    out_elems: int

    def to_json(self) -> Dict:
        return {
            "name": self.name,
            "op": self.opcode,
            "flops": self.flops,
            "bytes_read": self.bytes_read,
            "bytes_written": self.bytes_written,
            "out_elems": self.out_elems,
        }


def parse_shapes(text: str) -> List[Shape]:
    out = []
    for dtype, dims in _SHAPE_RE.findall(text):
        if dtype not in _DTYPE_BYTES:
            continue
        dims_t = tuple(int(d) for d in dims.split(",") if d) if dims else ()
        out.append(Shape(dtype, dims_t))
    return out


def _balanced(text: str, open_idx: int) -> int:
    """Index of the ')' matching the '(' at open_idx, or len(text)."""
    depth = 0
    for i in range(open_idx, len(text)):
        depth += text[i] == "("
        depth -= text[i] == ")"
        if depth == 0:
            return i
    return len(text)


def _split_top_commas(text: str) -> List[str]:
    parts, depth, start = [], 0, 0
    for i, ch in enumerate(text):
        if ch in "([{":
            depth += 1
        elif ch in ")]}":
            depth -= 1
        elif ch == "," and depth == 0:
            parts.append(text[start:i])
            start = i + 1
    parts.append(text[start:])
    return [p.strip() for p in parts if p.strip()]


def _parse_instr(line: str) -> Optional[Instr]:
    m = _INSTR_HEAD_RE.match(line)
    if not m:
        return None
    name, rhs = m.groups()
    rhs = rhs.strip()
    # Output type: tuple "( ... )" or scalar token like f32[8,4]{1,0}.
    if rhs.startswith("("):
        end = _balanced(rhs, 0)
        out_text, rest = rhs[: end + 1], rhs[end + 1:].strip()
    else:
        sp = rhs.find(" ")
        if sp < 0:
            return None
        out_text, rest = rhs[:sp], rhs[sp + 1:].strip()
    op_m = re.match(r"([\w\-]+)\(", rest)
    if not op_m:
        return None
    opcode = op_m.group(1)
    close = _balanced(rest, op_m.end() - 1)
    operand_text = rest[op_m.end(): close]
    attrs = rest[close + 1:]
    # Operands: "%name" / "name" / "f32[8]{0} %name" per comma-separated slot.
    operand_names = []
    for part in _split_top_commas(operand_text):
        tokens = part.split()
        if not tokens:
            continue
        m_id = _IDENT_RE.match(tokens[-1])
        if m_id:
            operand_names.append(m_id.group(1))
    called = re.findall(r"(?:calls|to_apply|body|condition)=%?([\w.\-]+)",
                        attrs)
    return Instr(
        name=name,
        opcode=opcode,
        out=parse_shapes(out_text),
        operand_names=operand_names,
        attrs=attrs,
        called=called,
    )


def parse_hlo_computations(text: str) -> Dict[str, List[Instr]]:
    """Parse HLO text into {computation: [instrs]} with resolved operands.

    The special key "__entry__" aliases the ENTRY computation.
    """
    comps: Dict[str, List[Instr]] = {}
    shapes: Dict[str, List[Shape]] = {}
    entry: Optional[str] = None
    current: Optional[str] = None

    for line in text.splitlines():
        stripped = line.strip()
        if not stripped or stripped.startswith(("//", "#")):
            continue
        if stripped == "}":
            current = None
            continue
        header = _COMP_HEADER_RE.match(stripped)
        if header and "=" not in stripped.split("(", 1)[0]:
            is_entry, cname, params_text, _ = header.groups()
            current = cname
            comps[current] = []
            if is_entry:
                entry = cname
            # Record parameter shapes: "param_0.1: f32[8], ..."
            for p in _split_top_commas(params_text):
                if ":" in p:
                    pname, ptype = p.split(":", 1)
                    shapes[pname.strip().lstrip("%")] = parse_shapes(ptype)
            continue
        bare = _COMP_HEADER_BARE_RE.match(stripped)
        if bare and "=" not in stripped:
            is_entry, cname = bare.groups()
            current = cname
            comps[current] = []
            if is_entry:
                entry = cname
            continue
        if current is None:
            continue
        instr = _parse_instr(line)
        if instr is None:
            continue
        comps[current].append(instr)
        shapes[instr.name] = instr.out

    # Pass 2: resolve operand shapes through the global table.
    for instrs in comps.values():
        for instr in instrs:
            instr.operands = [
                s for on in instr.operand_names for s in shapes.get(on, [])
            ]

    if entry is not None:
        comps["__entry__"] = comps[entry]
    return comps


def _dot_flops(instr: Instr) -> float:
    """2 * prod(out) * K, K from lhs shape + lhs_contracting_dims."""
    if not instr.out:
        return 0.0
    out_elems = instr.out[0].elems
    k = 1
    m = re.search(r"lhs_contracting_dims=\{([\d,]*)\}", instr.attrs)
    if m and instr.operands:
        lhs = instr.operands[0]
        for d in (int(x) for x in m.group(1).split(",") if x):
            if d < len(lhs.dims):
                k *= lhs.dims[d]
    elif instr.operands and instr.operands[0].dims:
        k = instr.operands[0].dims[-1]
    return 2.0 * out_elems * k


def _conv_flops(instr: Instr) -> float:
    """2 * prod(out) * (kernel_elems / cout); cout from dim_labels."""
    if len(instr.operands) < 2 or not instr.out:
        return 0.0
    out = instr.out[0]
    kernel = instr.operands[1]
    cout = out.dims[-1] if out.dims else 1
    m = re.search(r"dim_labels=\w+_\w+->(\w+)", instr.attrs)
    if m and out.dims:
        f_pos = m.group(1).find("f")
        if 0 <= f_pos < len(out.dims):
            cout = out.dims[f_pos]
    cout = max(cout, 1)
    return 2.0 * out.elems * (kernel.elems / cout)


def instr_flops(instr: Instr,
                comps: Dict[str, List[Instr]],
                depth: int = 0) -> float:
    """FLOPs of one instruction (recursing into fusions / maps / whiles)."""
    op = instr.opcode
    if op in _FREE_OPS or depth > 8:
        return 0.0
    if op == "dot":
        return _dot_flops(instr)
    if op == "convolution":
        return _conv_flops(instr)
    if op in ("fusion", "call", "map", "conditional"):
        return sum(
            instr_flops(i, comps, depth + 1)
            for c in instr.called
            for i in comps.get(c, []))
    if op == "while":
        body = sum(
            instr_flops(i, comps, depth + 1)
            for c in instr.called
            for i in comps.get(c, []))
        return body * _while_trip_count(instr)
    if op in ("reduce", "reduce-window"):
        return float(instr.operands[0].elems) if instr.operands else 0.0
    if op in _MOVEMENT_OPS:
        return 0.0
    weight = _TRANSCENDENTAL.get(op, 1)
    return float(instr.out_elems) * weight


def _while_trip_count(instr: Instr) -> int:
    """Best-effort trip count (XLA sometimes records known trip counts in
    backend_config); defaults to 1. Trace artifacts are lowered from the
    statically-unrolled graph (`model.unroll_static`) precisely so the
    kernel trace never depends on this heuristic."""
    m = re.search(r"trip_count[\"']?[:=][\"']?(\d+)", instr.attrs)
    return int(m.group(1)) if m else 1


# Ops that anchor a new kernel group under coalescing (real launches).
_ANCHOR_OPS = {
    "dot", "convolution", "reduce", "reduce-window", "while", "sort",
    "scatter", "rng", "rng-bit-generator", "custom-call", "fusion", "call",
    "select-and-scatter", "all-reduce", "all-gather",
}

# Layout-change ops whose traffic survives fusion (real memory passes).
_LAYOUT_OPS = {"copy", "transpose"}


def kernel_trace(hlo_text: str, coalesce: bool = False) -> List[KernelCost]:
    """Approximate per-kernel-launch costs for the entry computation.

    With `coalesce=True` (used on *unoptimized* HLO), runs of elementwise
    ops between anchors (dot/conv/reduce/...) are merged into the
    preceding anchor's kernel, approximating the fusion a real XLA:GPU
    compile performs: merged ops contribute FLOPs, replace the group's
    output bytes, and contribute no extra input traffic (producer->
    consumer stays in registers). Layout ops (copy/transpose) merge their
    launch but keep their memory traffic — fusion cannot elide a physical
    layout change.
    """
    comps = parse_hlo_computations(hlo_text)
    entry = comps.get("__entry__", [])
    kernels: List[KernelCost] = []

    def push(instr: Instr, flops: float):
        kernels.append(
            KernelCost(
                name=instr.name,
                opcode=instr.opcode,
                flops=flops,
                bytes_read=instr.in_bytes,
                bytes_written=instr.out_bytes,
                out_elems=instr.out_elems,
            ))

    for instr in entry:
        if instr.opcode in _FREE_OPS:
            continue
        if instr.opcode == "broadcast" and coalesce:
            continue  # fused into consumers by any real backend
        flops = instr_flops(instr, comps)
        br, bw = instr.in_bytes, instr.out_bytes
        if flops == 0.0 and br == 0 and bw == 0:
            continue
        if not coalesce:
            push(instr, flops)
            continue
        if instr.opcode in _ANCHOR_OPS or not kernels:
            push(instr, flops)
        elif instr.opcode in _LAYOUT_OPS:
            g = kernels[-1]
            g.flops += flops
            g.bytes_read += instr.in_bytes
            g.bytes_written += instr.out_bytes
            g.out_elems = max(g.out_elems, instr.out_elems)
        else:
            # Elementwise epilogue: fuse into the current group.
            g = kernels[-1]
            g.flops += flops
            g.bytes_written = max(g.bytes_written, instr.out_bytes)
            g.out_elems = max(g.out_elems, instr.out_elems)
    return kernels


def trace_summary(kernels: List[KernelCost]) -> Dict:
    return {
        "num_kernels": len(kernels),
        "total_flops": sum(k.flops for k in kernels),
        "total_bytes_read": sum(k.bytes_read for k in kernels),
        "total_bytes_written": sum(k.bytes_written for k in kernels),
    }
