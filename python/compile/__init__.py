"""rlarch build-time Python package (L1 Pallas kernels + L2 JAX model).

Nothing in this package runs on the request path: `aot.py` lowers
everything to HLO text once (`make artifacts`), and the Rust coordinator
executes the artifacts through PJRT.
"""
