"""L2: the R2D2 agent network (conv torso + Pallas LSTM core + dueling head).

This is the compute graph the paper profiles: SEED RL's central-inference
R2D2 agent. Sizes default to the small arcade suite in `rust/src/env`
(10x10x4 observations, 4 actions, ~260k parameters — the Atari-class
regime scaled to a CPU PJRT backend; all dims configurable).

Everything here is pure-functional: `params` is a nested dict (see
nn.flat_param_specs for the ABI order) and the two public graphs are

  apply_inference(params, h, c, obs)          -> (q, h', c')
  unroll(params, h0, c0, obs_seq)             -> (q_seq, h', c')   (scan)

The LSTM cell is the fused Pallas kernel from `kernels/lstm_cell.py`
(interpret=True), so it lowers into the same HLO module as the rest of
the graph and runs on the CPU PJRT client from Rust.
"""

from __future__ import annotations

import dataclasses
from typing import Tuple

import jax
import jax.numpy as jnp

from . import nn
from .kernels import dueling_head, lstm_cell


@dataclasses.dataclass(frozen=True)
class AgentConfig:
    """Shapes of the R2D2 agent. The defaults match the Rust env suite."""

    obs_size: int = 10          # square observation, S x S
    obs_channels: int = 4       # frame-stack depth
    num_actions: int = 4
    conv1_filters: int = 16
    conv2_filters: int = 32
    conv1_stride: int = 1
    conv2_stride: int = 2
    torso_dim: int = 128        # dense after flatten
    lstm_hidden: int = 128
    head_dim: int = 64
    lstm_block_b: int = 32      # Pallas batch tile: 4x the MXU row
                                # utilization of 8 for +0.1 MiB VMEM
                                # (EXPERIMENTS.md §Perf L1)

    @property
    def obs_shape(self) -> Tuple[int, int, int]:
        return (self.obs_size, self.obs_size, self.obs_channels)

    @property
    def conv_out_dim(self) -> int:
        # Two SAME convs with configurable strides.
        s1 = -(-self.obs_size // self.conv1_stride)
        s2 = -(-s1 // self.conv2_stride)
        return s2 * s2 * self.conv2_filters


def init_params(key, cfg: AgentConfig):
    """Initialize the full parameter pytree (nested dicts, sorted keys)."""
    ks = jax.random.split(key, 7)
    return {
        "conv1": nn.init_conv(ks[0], 3, 3, cfg.obs_channels, cfg.conv1_filters),
        "conv2": nn.init_conv(ks[1], 3, 3, cfg.conv1_filters, cfg.conv2_filters),
        "torso": nn.init_dense(ks[2], cfg.conv_out_dim, cfg.torso_dim),
        "lstm": nn.init_lstm(ks[3], cfg.torso_dim, cfg.lstm_hidden),
        "head": nn.init_dense(ks[4], cfg.lstm_hidden, cfg.head_dim),
        "value": nn.init_dense(ks[5], cfg.head_dim, 1),
        "advantage": nn.init_dense(ks[6], cfg.head_dim, cfg.num_actions),
    }


def initial_state(batch: int, cfg: AgentConfig):
    z = jnp.zeros((batch, cfg.lstm_hidden), jnp.float32)
    return z, z


def torso(params, obs, cfg: AgentConfig):
    """Conv torso: [B,S,S,C] float obs (already /255 scaled) -> [B,torso]."""
    x = nn.relu(nn.conv2d(params["conv1"], obs, stride=cfg.conv1_stride))
    x = nn.relu(nn.conv2d(params["conv2"], x, stride=cfg.conv2_stride))
    x = x.reshape((x.shape[0], -1))
    return nn.relu(nn.dense(params["torso"], x))


def q_head(params, h):
    """Dueling Q-head over LSTM output h: [B,H] -> [B,A] (Pallas epilogue)."""
    z = nn.relu(nn.dense(params["head"], h))
    v = nn.dense(params["value"], z)        # [B, 1]
    a = nn.dense(params["advantage"], z)    # [B, A]
    return dueling_head(v, a)


def apply_inference(params, h, c, obs, cfg: AgentConfig):
    """Single-step batched inference — the SEED central-inference graph.

    Args:
      params: agent pytree.
      h, c: [B, H] recurrent state (owned by the Rust coordinator, one slot
        per actor, gathered into the batch by the inference batcher).
      obs: [B, S, S, C] float32 observation (pre-scaled to [0,1]).

    Returns:
      (q [B, A], h' [B, H], c' [B, H])
    """
    x = torso(params, obs, cfg)
    h2, c2 = lstm_cell(x, h, c, params["lstm"]["wx"], params["lstm"]["wh"],
                       params["lstm"]["b"], block_b=cfg.lstm_block_b)
    return q_head(params, h2), h2, c2


def unroll(params, h0, c0, obs_seq, cfg: AgentConfig):
    """Unroll the agent over a [T, B, S, S, C] observation sequence.

    Uses lax.scan over time (compiled once, not unrolled T times — see
    EXPERIMENTS.md §Perf L2 for the scan-vs-unroll measurement).

    Returns:
      (q_seq [T, B, A], (h_T, c_T))
    """

    def step(state, obs_t):
        h, c = state
        x = torso(params, obs_t, cfg)
        h2, c2 = lstm_cell(x, h, c, params["lstm"]["wx"],
                           params["lstm"]["wh"], params["lstm"]["b"],
                           block_b=cfg.lstm_block_b)
        return (h2, c2), q_head(params, h2)

    (h_t, c_t), q_seq = jax.lax.scan(step, (h0, c0), obs_seq)
    return q_seq, (h_t, c_t)


def unroll_static(params, h0, c0, obs_seq, cfg: AgentConfig):
    """Python-loop unroll (T copies of the cell in the graph).

    Used only for kernel-trace extraction: the per-timestep kernels appear
    individually in the optimized HLO entry computation, matching what an
    nvprof-style GPU profile of the unrolled recurrent net would record
    (lax.scan lowers to a `while`, hiding the per-step launches).
    """
    h, c = h0, c0
    qs = []
    for t in range(obs_seq.shape[0]):
        x = torso(params, obs_seq[t], cfg)
        h, c = lstm_cell(x, h, c, params["lstm"]["wx"], params["lstm"]["wh"],
                         params["lstm"]["b"], block_b=cfg.lstm_block_b)
        qs.append(q_head(params, h))
    return jnp.stack(qs), (h, c)


# ---------------------------------------------------------------------------
# IMPALA (V-trace) baseline agent: same torso+LSTM, policy+value heads.
# ---------------------------------------------------------------------------

def init_vtrace_params(key, cfg: AgentConfig):
    ks = jax.random.split(key, 6)
    return {
        "conv1": nn.init_conv(ks[0], 3, 3, cfg.obs_channels, cfg.conv1_filters),
        "conv2": nn.init_conv(ks[1], 3, 3, cfg.conv1_filters, cfg.conv2_filters),
        "torso": nn.init_dense(ks[2], cfg.conv_out_dim, cfg.torso_dim),
        "lstm": nn.init_lstm(ks[3], cfg.torso_dim, cfg.lstm_hidden),
        "policy": nn.init_dense(ks[4], cfg.lstm_hidden, cfg.num_actions),
        "value": nn.init_dense(ks[5], cfg.lstm_hidden, 1),
    }


def vtrace_unroll(params, h0, c0, obs_seq, cfg: AgentConfig):
    """[T,B,...] -> (logits [T,B,A], values [T,B], final state)."""

    def step(state, obs_t):
        h, c = state
        x = torso(params, obs_t, cfg)
        h2, c2 = lstm_cell(x, h, c, params["lstm"]["wx"],
                           params["lstm"]["wh"], params["lstm"]["b"],
                           block_b=cfg.lstm_block_b)
        logits = nn.dense(params["policy"], h2)
        value = nn.dense(params["value"], h2)[:, 0]
        return (h2, c2), (logits, value)

    (h_t, c_t), (logits, values) = jax.lax.scan(step, (h0, c0), obs_seq)
    return logits, values, (h_t, c_t)
